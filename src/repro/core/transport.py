"""Transport — the bucket-exchange layer of the disk tier, made pluggable.

Every exchange in the pipeline (shuffle slice exchange, relabel scatter,
redistribute, per-hop walk-frontier exchange, history collect) has the same
shape: sender kernels append tagged runs into a *destination bucket's* inbox
store, a bulk-synchronous barrier passes, and the receiver kernel drains the
inbox in lexicographic `{sender}_{seq}` tag order.  Until this module, that
contract was welded to a shared filesystem (senders wrote directly into the
receiver's store directory).  `Transport` lifts it into an interface so the
same bucket kernels run over either backend:

  FilesystemTransport  the reference implementation: `channel()` IS the
                       destination BlockStore, so a send is a local append —
                       today's `{sender}_{seq}` convention, unchanged.  On a
                       shared filesystem every exchanged byte crosses the
                       interconnect twice (sender -> shared store, shared
                       store -> receiver), the cost the socket backend halves.
  SocketTransport      length-prefixed framed TCP with per-connection
                       sequence numbers: a send frames one run (header JSON +
                       raw column-major payload) to the ExchangeServer that
                       owns the destination bucket, which writes it as the
                       same `run_{sender}_{seq}.npy` file the filesystem
                       backend would have produced (`.part` staging + atomic
                       rename before the ack, so an acked run survives any
                       receiver process crash; fsync opt-in for host-crash
                       durability).  Receivers therefore
                       attach *identical* stores — outputs are bit-identical
                       across backends — while the bytes cross the wire once
                       and workers can live on different hosts.

Memory discipline: a frame carries exactly one run (writer-bounded at
cfg.chunk_edges rows), the sender transmits straight from the stacked column
array, and the receiver materializes one frame at a time — both ends report
their buffers to the MemoryGauge, so the O(chunk) bound of the disk tier
holds across the wire and is *asserted*, not assumed.

Failure discipline: a crashed exchange leaves (a) stale complete runs from
the dead attempt and (b) partially-received `.part` frames.  Both backends
expose the same sweep — `clean_inboxes()` removes a named inbox wholesale
(the "cleaned BEFORE the senders run" invariant of drive_shuffle/drive_walks)
and `sweep_partial_frames()` clears orphaned `.part` staging files — so the
PhaseOrchestrator's resume path replays a crashed exchange from the sender's
checkpointed runs no matter which backend carried the original attempt.
Frame sequence numbers must arrive contiguous per connection; a gap means a
lost or reordered frame and the server refuses it (corruption guard, same
spirit as MonotoneLookup's regression check).
"""

from __future__ import annotations

import dataclasses
import json
import os
import re
import shutil
import socket
import struct
import threading
from typing import Dict, List, Optional, Sequence

import numpy as np

from .trace import get_tracer
from .blockstore import (
    BlockStore, IOLedger, MemoryGauge, auto_run_tag, clean_store,
    stack_columns)
from .shardmap import frame_version_ok

_MAGIC = b"EXG1"
_KIND_DATA = 0
_KIND_CLEAN = 1
# Raw-file shard migration (rebalancer traffic): chunked byte-exact copies
# of bucket files, riding the same framing/ack/.part discipline as DATA.
_KIND_MIGRATE = 3
_HDR = struct.Struct("!4sBI")     # magic, kind, header_len
_PLEN = struct.Struct("!Q")       # payload_len
_ACK = struct.Struct("!BI")       # status (0 ok), message_len
# A corrupt length prefix must fail fast, not allocate: no legal frame
# carries more than one writer-bounded run, so anything past 8 GiB is noise —
# and a legal header or ack message is a few hundred bytes, so those are
# bounded far tighter (the O(chunk) receive buffer must not be defeatable by
# a garbage length field).
_MAX_FRAME_BYTES = 1 << 33
_MAX_HEADER_BYTES = 1 << 20
_SOCKET_TIMEOUT = 180.0

PART_SUFFIX = ".part"


class TransportError(RuntimeError):
    """A peer refused or corrupted an exchange frame."""


@dataclasses.dataclass
class TransportStats:
    """Wire-level accounting (the network twin of IOLedger): one frame per
    exchanged run, bytes counted once — the single-traversal term in the
    external.py I/O-cost table."""

    frames_sent: int = 0
    bytes_sent: int = 0
    frames_recv: int = 0
    bytes_recv: int = 0
    # Rebalancer traffic (MIGRATE frames), kept apart from exchange bytes:
    # migration is a placement cost the rebalancer must amortize, not part
    # of the pipeline's single-traversal exchange term.
    migrate_frames: int = 0
    migrate_bytes: int = 0

    def add(self, other: "TransportStats") -> None:
        self.frames_sent += other.frames_sent
        self.bytes_sent += other.bytes_sent
        self.frames_recv += other.frames_recv
        self.bytes_recv += other.bytes_recv
        self.migrate_frames += other.migrate_frames
        self.migrate_bytes += other.migrate_bytes


def sweep_partial_frames(workdir: str) -> None:
    """Remove orphaned `.part` staging files (a receive killed mid-frame).

    Shared resume sweep: PhaseOrchestrator calls this next to
    clean_cascade_stores so a resumed run starts from complete runs only —
    the socket twin of sweeping stale `{sender}_{seq}` files.  The walk is
    fully recursive because namespaced exchanges (one `job...` subdir per
    queued job) nest store directories one level deeper than the flat
    layout; attach() already ignores non-`.npy` names, so this is hygiene
    plus disk reclamation, never correctness-by-luck.
    """
    if not os.path.isdir(workdir):
        return
    for root, _dirs, files in os.walk(workdir):
        for f in files:
            if f.endswith(PART_SUFFIX):
                os.unlink(os.path.join(root, f))


def _check_store_name(name: str) -> str:
    if not name or os.sep in name or (os.altsep and os.altsep in name) \
            or name in (".", "..") or name.startswith("."):
        raise TransportError(f"illegal store name in frame: {name!r}")
    return name


def _check_subdir(name: str) -> str:
    """Validate a frame's exchange-namespace component: one path segment,
    same character discipline as store names (a namespaced inbox lives at
    `<workdir>/<subdir>/<store>`, never deeper, never outside)."""
    if not name or os.sep in name or (os.altsep and os.altsep in name) \
            or name in (".", "..") or name.startswith("."):
        raise TransportError(f"illegal exchange namespace in frame: {name!r}")
    return name


def _check_rel_path(path: str) -> str:
    """Validate a MIGRATE frame's destination path: slash-separated, every
    segment store-name-disciplined, bounded depth (the deepest legal layout
    is `<namespace>/<store>/<run file>`)."""
    parts = str(path).split("/")
    if not 1 <= len(parts) <= 4:
        raise TransportError(f"illegal migration path depth: {path!r}")
    for seg in parts:
        _check_store_name(seg)
    return "/".join(parts)


# Store/file naming encodes the destination bucket (`..._b003`,
# `..._b003_sorted`, `walks_b003.npy`); this is the ONE parser of that
# convention, shared by the receive-side skew attribution below and the
# rebalancer's bucket-file discovery in core/cluster.py.
_STORE_BUCKET_RE = re.compile(r"_b(\d{3})(?=$|[._])")


def store_bucket(name: str) -> Optional[int]:
    """Bucket id encoded in a store/file name, or None."""
    m = _STORE_BUCKET_RE.search(name)
    return int(m.group(1)) if m else None


class Transport:
    """Sender/receiver pair over which bucket kernels exchange tagged runs.

    channel(dest, name)   sender side: a run sink with BlockStore's
                          `append_run(*cols, tag=)` signature, bound to the
                          inbox `name` of bucket `dest`.
    drain_inbox(name)     receiver side: the inbox as a BlockStore, runs in
                          lexicographic tag (== sender) order.  Callable only
                          after the phase barrier — both backends guarantee
                          every send is fully written at the receiver before
                          the sending kernel returns.
    clean_inboxes(names)  pre-barrier sweep of multi-writer inboxes (stale
                          complete runs AND partial frames from a crashed
                          attempt) — drivers call it BEFORE the senders run.
    flush()               drain in-flight sends (no-op for both current
                          backends: fs writes are synchronous, socket sends
                          are acked per frame).
    """

    kind = "?"

    def channel(self, dest_bucket: int, name: str,
                columns: Sequence[str] = ("src", "dst"), dtype=np.int64):
        raise NotImplementedError

    def channels(self, name_of, nparts: int,
                 columns: Sequence[str] = ("src", "dst"),
                 dtype=np.int64) -> List:
        """One channel per destination bucket (`name_of(d)` names d's inbox) —
        the partition_runs sink list."""
        return [self.channel(d, name_of(d), columns=columns, dtype=dtype)
                for d in range(nparts)]

    def drain_inbox(self, name: str, columns: Sequence[str] = ("src", "dst"),
                    dtype=np.int64) -> BlockStore:
        """Shared by both backends (one implementation, one receive path —
        the drain twin of stack_columns): the inbox always lives on the
        local filesystem, whether a local append or the colocated
        ExchangeServer put the runs there."""
        return BlockStore.attach(self.workdir, name, self.ledger,
                                 columns=columns, dtype=dtype, gauge=self.gauge)

    def clean_inboxes(self, names: Sequence[str]) -> None:
        raise NotImplementedError

    def flush(self) -> None:
        pass

    def rebind(self, ledger: IOLedger,
               gauge: Optional[MemoryGauge] = None) -> None:
        """Point accounting at a new ledger/gauge and reset per-task stats —
        pool workers reuse one transport (and its TCP connections) across
        kernel invocations, but each task accounts into its own objects."""
        self.ledger = ledger
        self.gauge = gauge if gauge is not None else MemoryGauge()
        self.stats = TransportStats()

    def close(self) -> None:
        pass

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()


class _CountingChannel:
    """FilesystemTransport's run sink: the destination BlockStore plus
    wire-equivalent stats, so `TransportStats` means the same thing on both
    backends — bytes handed to the exchange, counted once per run."""

    __slots__ = ("_store", "_stats")

    def __init__(self, store: BlockStore, stats: TransportStats):
        self._store = store
        self._stats = stats

    def append_run(self, *cols: np.ndarray, tag: Optional[str] = None) -> int:
        i = self._store.append_run(*cols, tag=tag)
        self._stats.frames_sent += 1
        self._stats.bytes_sent += (self._store.run_rows(i) * self._store.ncols
                                   * self._store.dtype.itemsize)
        return i


class FilesystemTransport(Transport):
    """The `{sender}_{seq}` shared-filesystem convention as a Transport: a
    channel is the destination store itself (send == local append), drain is
    BlockStore.attach, and the inbox sweep is clean_store + partial-frame
    removal.  This is the reference implementation the socket backend must be
    bit-identical to."""

    kind = "fs"

    def __init__(self, workdir: str, ledger: IOLedger,
                 gauge: Optional[MemoryGauge] = None):
        self.workdir = workdir
        self.ledger = ledger
        self.gauge = gauge if gauge is not None else MemoryGauge()
        self.stats = TransportStats()

    def channel(self, dest_bucket: int, name: str,
                columns: Sequence[str] = ("src", "dst"), dtype=np.int64):
        return _CountingChannel(
            BlockStore(self.workdir, name, self.ledger, columns=columns,
                       dtype=dtype, gauge=self.gauge),
            self.stats)

    def clean_inboxes(self, names: Sequence[str]) -> None:
        for name in names:
            clean_store(self.workdir, name)


# ---------------------------------------------------------------------------
# socket backend
# ---------------------------------------------------------------------------


def _recv_exact(sock: socket.socket, n: int) -> bytearray:
    # Returned as the bytearray it was received into (no bytes() copy): a
    # frame payload is one writer-bounded run, and copying it would silently
    # double the receiver's resident bytes per frame.
    buf = bytearray(n)
    view = memoryview(buf)
    got = 0
    while got < n:
        r = sock.recv_into(view[got:], n - got)
        if r == 0:
            raise TransportError("peer closed mid-frame")
        got += r
    return buf


def _send_frame(sock: socket.socket, kind: int, meta: Dict,
                payload=b"") -> None:
    header = json.dumps(meta).encode()
    sock.sendall(_HDR.pack(_MAGIC, kind, len(header)))
    sock.sendall(header)
    sock.sendall(_PLEN.pack(len(payload)))
    if len(payload):
        sock.sendall(payload)


def _recv_ack(sock: socket.socket) -> None:
    status, mlen = _ACK.unpack(_recv_exact(sock, _ACK.size))
    if mlen > _MAX_HEADER_BYTES:
        raise TransportError(f"oversized ack message ({mlen} bytes): torn ack")
    msg = _recv_exact(sock, mlen).decode() if mlen else ""
    if status != 0:
        raise TransportError(f"exchange peer refused frame: {msg}")


class _SocketChannel:
    """Sender-side run sink: frames each appended run and ships it to the
    ExchangeServer owning the destination bucket.  Mirrors
    BlockStore.append_run exactly (same stacking, dtype coercion, and
    auto-naming) so the receiver's files are bit-identical to the filesystem
    backend's."""

    def __init__(self, transport: "SocketTransport", addr: str, name: str,
                 columns: Sequence[str], dtype):
        self._tr = transport
        self._addr = addr
        self.name = name
        self.columns = tuple(columns)
        self.dtype = np.dtype(dtype)
        self._auto_seq = 0

    def append_run(self, *cols: np.ndarray, tag: Optional[str] = None) -> int:
        # stack_columns/auto_run_tag are the SAME code BlockStore.append_run
        # runs, so the receiver's files are bit-identical to a local append;
        # multi-writer exchanges always pass explicit {sender}_{seq} tags.
        arr = stack_columns(cols, self.columns, self.dtype)
        if tag is None:
            tag = auto_run_tag(self._auto_seq)
        self._auto_seq += 1
        self._tr.gauge.track(arr.shape[0])
        meta = {
            "store": self.name,
            "tag": tag,
            "dtype": self.dtype.str,
            "rows": int(arr.shape[0]),
            "ncols": int(arr.shape[1]),
        }
        if self._tr.namespace is not None:
            meta["subdir"] = self._tr.namespace
        # Flat byte view (len() of a 2-D memoryview counts ROWS, not bytes);
        # zero-copy when contiguous, which np.stack output always is.
        payload = (memoryview(arr).cast("B") if arr.flags.c_contiguous
                   else arr.tobytes())
        tracer = get_tracer()
        if tracer.enabled:
            # One "wire" span per frame: send + durable-receive ack — the
            # synchronous exchange cost a phase actually pays per run.
            with tracer.span(f"send:{self.name}", cat="wire",
                             bytes=int(arr.nbytes)):
                self._tr._rpc(self._addr, _KIND_DATA, meta, payload)
        else:
            self._tr._rpc(self._addr, _KIND_DATA, meta, payload)
        self._tr.stats.frames_sent += 1
        self._tr.stats.bytes_sent += arr.nbytes
        return self._auto_seq - 1


class SocketTransport(Transport):
    """Framed-TCP exchange: one lazy connection per peer server, one frame
    per run, synchronous ack after the receiver has written and atomically
    renamed the run file (its ExchangeServer's fsync flag upgrades that to
    host-crash durability).  Ack-per-frame means (a) the send buffer is exactly one in-flight
    run — the O(chunk) gauge bound holds on the wire — and (b) when a sending
    kernel returns, every run it shipped is attachable at the receiver, so
    the phase barrier needs no extra flush round.

    `peers[d]` is the "host:port" of the ExchangeServer owning bucket d.
    Inbox drains read the local filesystem (this process must be colocated
    with the server that owns its buckets — on one host, every process is).

    `namespace` scopes every frame to a per-job inbox subdirectory at the
    receiver (`<server workdir>/<namespace>/<store>`): concurrent jobs from
    the queue share one ExchangeServer per host without their same-named
    inboxes (edges, owned, walk frontiers) ever colliding.  The sender's
    own `workdir` is already the namespaced job directory, so drains stay
    symmetric with receives.
    """

    kind = "socket"

    def __init__(self, workdir: str, ledger: IOLedger,
                 gauge: Optional[MemoryGauge] = None,
                 peers: Sequence[str] = (),
                 namespace: Optional[str] = None,
                 map_version: Optional[int] = None):
        if not peers:
            raise ValueError("SocketTransport needs one peer address per bucket")
        self.workdir = workdir
        self.ledger = ledger
        self.gauge = gauge if gauge is not None else MemoryGauge()
        self.peers = tuple(str(p) for p in peers)
        self.namespace = _check_subdir(namespace) if namespace else None
        # Shard-map version this transport's routes were computed under.
        # Stamped into every frame as `mapv`; receivers ratchet a minimum at
        # rebalance barriers and refuse anything older (stale-route fence).
        # None = unversioned sender (standalone transports), never refused.
        self.map_version = None if map_version is None else int(map_version)
        self.stats = TransportStats()
        self._conns: Dict[str, List] = {}   # addr -> [socket, next_seq]

    # -- wire ---------------------------------------------------------------
    def _conn(self, addr: str) -> List:
        ent = self._conns.get(addr)
        if ent is None:
            host, port = addr.rsplit(":", 1)
            s = socket.create_connection((host, int(port)),
                                         timeout=_SOCKET_TIMEOUT)
            s.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
            ent = self._conns[addr] = [s, 0]
        return ent

    def _rpc(self, addr: str, kind: int, meta: Dict, payload=b"") -> None:
        ent = self._conn(addr)
        meta = dict(meta)
        meta["seq"] = ent[1]
        if self.map_version is not None:
            meta["mapv"] = self.map_version
        try:
            _send_frame(ent[0], kind, meta, payload)
            _recv_ack(ent[0])
        except (OSError, TransportError):
            # A failed frame poisons the connection's seq contract — drop it
            # so a retry (resumed phase) starts a fresh, zero-based stream.
            try:
                ent[0].close()
            finally:
                self._conns.pop(addr, None)
            raise
        ent[1] += 1

    # -- Transport interface --------------------------------------------------
    def channel(self, dest_bucket: int, name: str,
                columns: Sequence[str] = ("src", "dst"), dtype=np.int64):
        return _SocketChannel(self, self.peers[dest_bucket], name, columns, dtype)

    # Names per CLEAN frame: keeps the JSON header far under the server's
    # _MAX_HEADER_BYTES bound at any nb/walk-length (walk_gc cleans
    # nb*(2L+3) names in one call).
    _CLEAN_BATCH = 2048

    def clean_inboxes(self, names: Sequence[str]) -> None:
        """CLEAN frames to every distinct peer server: each removes the
        named inbox directories (complete runs AND `.part` partial frames)
        on ITS workdir and acks — so the pre-senders invariant holds
        cluster-wide, not just on the driver's host.  When several loopback
        servers share one workdir the broadcast makes the later sweeps
        idempotent no-ops; the transport deliberately does not model which
        peers are colocated, because on distinct hosts every server
        genuinely needs the CLEAN."""
        names = list(names)
        if not names:
            return
        with get_tracer().span("clean_inboxes", cat="wire",
                               stores=len(names)):
            for addr in dict.fromkeys(self.peers):   # distinct, stable order
                for lo in range(0, len(names), self._CLEAN_BATCH):
                    meta = {"stores": names[lo : lo + self._CLEAN_BATCH]}
                    if self.namespace is not None:
                        meta["subdir"] = self.namespace
                    self._rpc(addr, _KIND_CLEAN, meta)

    def send_file(self, addr: str, src_path: str, rel_path: str,
                  chunk_bytes: int = 4 << 20) -> int:
        """MIGRATE a raw local file to the server at `addr`, chunked.

        The receiver stages bytes in `<rel_path>.part` and atomically
        renames + acks on the final chunk (ack-after-durable, the DATA
        discipline) — once this returns, the caller may unlink its local
        copy.  Bytes are copied verbatim, so a migrated bucket file is
        bit-identical by construction.  `rel_path` is relative to the
        receiver's workdir (slash separated; spans namespace subdirs, so
        migration moves every job's data for a bucket, which is why it does
        NOT take this transport's own `namespace`).  Returns bytes sent.
        """
        rel = _check_rel_path(rel_path)
        total = os.path.getsize(src_path)
        sent = 0
        with get_tracer().span(f"migrate:{rel}", cat="wire", bytes=total), \
                open(src_path, "rb") as f:
            while True:
                data = f.read(chunk_bytes)
                if not data and sent < total:
                    raise TransportError(
                        f"{src_path} shrank mid-migration ({sent}/{total})")
                self._rpc(addr, _KIND_MIGRATE,
                          {"path": rel, "offset": sent, "total": total}, data)
                if data:
                    self.ledger.read(len(data))
                self.stats.migrate_frames += 1
                self.stats.migrate_bytes += len(data)
                sent += len(data)
                if sent >= total:
                    return total

    def purge_namespace(self) -> None:
        """Remove THIS transport's entire namespace subdirectory on every
        peer server (and locally): the dead-letter GC — a job parked after
        exhausting its lease budget must not leave partial stores behind.
        Only meaningful on a namespaced transport; the wire op is refused by
        the server otherwise (an un-namespaced purge would be `rm -rf` of
        the host workdir)."""
        if self.namespace is None:
            raise TransportError("purge_namespace needs a namespaced transport")
        for addr in dict.fromkeys(self.peers):
            self._rpc(addr, _KIND_CLEAN,
                      {"stores": [], "subdir": self.namespace, "purge": True})

    def close(self) -> None:
        for ent in self._conns.values():
            try:
                ent[0].close()
            except OSError:
                pass
        self._conns.clear()


class ExchangeServer:
    """Receiver half of SocketTransport: accepts peer connections and writes
    each DATA frame as `run_{tag}.npy` in the named inbox store — staged as
    `.part` and atomically renamed, acked only after the rename, so a
    crashed receive can never surface a torn run (attach() ignores `.part`;
    sweep_partial_frames reclaims them).  CLEAN frames remove inbox
    directories wholesale (the pre-senders sweep, executed on the receiver's
    own filesystem).

    One bounded frame is resident per connection (payload = one
    writer-bounded run), tracked in `gauge`; file writes are charged to
    `ledger` exactly as a local append_run would be, so a partitioned
    driver's aggregate accounting stays comparable across backends.
    Per-connection sequence numbers must arrive contiguous from 0 — a gap is
    a lost/reordered frame and the frame is refused (corruption guard).
    """

    def __init__(self, workdir: str, host: str = "127.0.0.1", port: int = 0,
                 fsync: bool = False):
        # `fsync=True` upgrades the ack guarantee from process-crash
        # durability (written + atomically renamed; the page cache is the
        # OS's) to host-crash durability (file + directory fsync before the
        # ack) at a large per-frame cost.  The default matches the rest of
        # the disk tier — checkpoint state files are not fsynced either, so
        # power loss is out of scope repo-wide unless opted into.
        self.fsync = fsync
        self.workdir = workdir
        os.makedirs(workdir, exist_ok=True)
        self.ledger = IOLedger()
        self.gauge = MemoryGauge()
        self.stats = TransportStats()
        # Stale-route fence: data-bearing frames stamped with a shard-map
        # version below this minimum are refused (a sender that missed a
        # rebalance barrier must not deliver bytes to the old owner).
        # Monotone ratchet — see set_min_map_version.
        self.min_map_version = 0
        self._lock = threading.Lock()
        self._sock = socket.create_server((host, port))
        bound = self._sock.getsockname()
        self.addr = f"{bound[0]}:{bound[1]}"
        self._live_conns: set = set()
        self._stopping = False
        self._accept_thread = threading.Thread(
            target=self._accept_loop, name=f"exchange-server-{bound[1]}",
            daemon=True)
        self._accept_thread.start()

    # -- receive loop ---------------------------------------------------------
    def _accept_loop(self) -> None:
        while True:
            try:
                conn, _ = self._sock.accept()
            except OSError:
                return   # listening socket closed by stop()
            conn.settimeout(_SOCKET_TIMEOUT)
            with self._lock:
                self._live_conns.add(conn)
            threading.Thread(target=self._serve_conn, args=(conn,),
                             daemon=True).start()

    def _serve_conn(self, conn: socket.socket) -> None:
        expect_seq = 0
        try:
            with conn:
                while True:
                    # Idle between frames is NOT an error: peers hold their
                    # connection across phase barriers (the driver's CLEAN
                    # channel idles for a whole phase; a sender kernel may
                    # sort for minutes between appends), so wait unbounded
                    # for the next frame to START.  Once one starts, a stall
                    # mid-frame means a hung/dead peer — that times out.
                    conn.settimeout(None)
                    try:
                        first = conn.recv(1)
                    except OSError:
                        return
                    if not first:
                        return   # clean EOF between frames
                    conn.settimeout(_SOCKET_TIMEOUT)
                    try:
                        head = first + _recv_exact(conn, _HDR.size - 1)
                        magic, kind, hlen = _HDR.unpack(head)
                        if magic != _MAGIC:
                            raise TransportError("bad frame magic")
                        if hlen > _MAX_HEADER_BYTES:
                            raise TransportError(
                                f"frame header {hlen} bytes exceeds bound")
                        meta = json.loads(_recv_exact(conn, hlen).decode())
                        (plen,) = _PLEN.unpack(_recv_exact(conn, _PLEN.size))
                        if plen > _MAX_FRAME_BYTES:
                            raise TransportError(
                                f"frame payload {plen} exceeds bound")
                        # Cross-check the raw length prefix against the
                        # header BEFORE allocating: the receive buffer must
                        # be bounded by the writer-bounded run the header
                        # describes (O(chunk)), not by whatever a corrupt
                        # prefix claims.
                        if kind == _KIND_DATA:
                            expect = (int(meta["rows"]) * int(meta["ncols"])
                                      * np.dtype(meta["dtype"]).itemsize)
                            if plen != expect:
                                raise TransportError(
                                    f"payload length {plen} != header's "
                                    f"rows*ncols*itemsize ({expect}) — "
                                    "corrupt or truncated frame")
                        elif kind == _KIND_MIGRATE:
                            if int(meta["offset"]) + plen > int(meta["total"]):
                                raise TransportError(
                                    f"migration chunk overruns declared "
                                    f"total ({meta['offset']}+{plen} > "
                                    f"{meta['total']})")
                        elif plen:
                            raise TransportError(
                                f"unexpected {plen}-byte payload on "
                                f"control frame kind {kind}")
                        payload = _recv_exact(conn, plen) if plen else b""
                        if meta.get("seq") != expect_seq:
                            raise TransportError(
                                f"frame seq {meta.get('seq')} != expected "
                                f"{expect_seq}: lost or reordered frame")
                        self._handle(kind, meta, payload)
                        expect_seq += 1
                        conn.sendall(_ACK.pack(0, 0))
                    except (TransportError, TypeError, ValueError, KeyError,
                            json.JSONDecodeError, OSError) as e:
                        # OSError covers receiver-side disk failures (ENOSPC,
                        # EACCES in _handle_data) and mid-frame socket
                        # stalls alike: NACK with the real cause so the
                        # sender's TransportError names it instead of
                        # reporting a bare closed connection.
                        msg = str(e).encode()[:4096]
                        try:
                            conn.sendall(_ACK.pack(1, len(msg)) + msg)
                        except OSError:
                            pass
                        return
        except OSError:
            return
        finally:
            with self._lock:
                self._live_conns.discard(conn)

    def set_min_map_version(self, version: int) -> None:
        """Ratchet the stale-route fence (monotone: never lowers)."""
        with self._lock:
            if int(version) > self.min_map_version:
                self.min_map_version = int(version)

    def _handle(self, kind: int, meta: Dict, payload: bytes) -> None:
        if kind in (_KIND_DATA, _KIND_MIGRATE) and not frame_version_ok(
                meta.get("mapv"), self.min_map_version):
            raise TransportError(
                f"stale shard-map route: frame mapv={meta.get('mapv')} < "
                f"server minimum {self.min_map_version}")
        if kind == _KIND_DATA:
            self._handle_data(meta, payload)
        elif kind == _KIND_MIGRATE:
            self._handle_migrate(meta, payload)
        elif kind == _KIND_CLEAN:
            root = self.workdir
            if meta.get("subdir") is not None:
                root = os.path.join(root, _check_subdir(str(meta["subdir"])))
            if meta.get("purge"):
                # Whole-namespace removal (dead-letter GC).  Refused without
                # a subdir: an un-scoped purge would be the host workdir.
                if meta.get("subdir") is None:
                    raise TransportError("purge frame without a namespace")
                shutil.rmtree(root, ignore_errors=True)
                return
            for name in meta["stores"]:
                clean_store(root, _check_store_name(name))
        else:
            raise TransportError(f"unknown frame kind {kind}")

    def _handle_data(self, meta: Dict, payload: bytes) -> None:
        name = _check_store_name(meta["store"])
        tag = str(meta["tag"])
        if "/" in tag or ".." in tag:
            raise TransportError(f"illegal run tag: {tag!r}")
        dtype = np.dtype(meta["dtype"])
        rows, ncols = int(meta["rows"]), int(meta["ncols"])
        if rows * ncols * dtype.itemsize != len(payload):
            raise TransportError(
                f"payload length {len(payload)} != rows*ncols*itemsize "
                f"({rows}x{ncols}x{dtype.itemsize}) — truncated frame")
        arr = np.frombuffer(payload, dtype=dtype).reshape(rows, ncols)
        root = self.workdir
        if meta.get("subdir") is not None:
            root = os.path.join(root, _check_subdir(str(meta["subdir"])))
        store_dir = os.path.join(root, name)
        os.makedirs(store_dir, exist_ok=True)
        final = os.path.join(store_dir, f"run_{tag}.npy")
        part = final + PART_SUFFIX
        # Written and atomically renamed BEFORE the ack: the sender's phase
        # checkpoints (and GC frees its input stores) on the strength of
        # this ack, so a receiver PROCESS crash can never lose or tear an
        # acked run.  With fsync=True the same holds across a receiver HOST
        # crash (file + directory fsync first).
        with open(part, "wb") as f:
            np.save(f, arr)
            if self.fsync:
                f.flush()
                os.fsync(f.fileno())
        os.replace(part, final)   # atomic: never a torn run file
        if self.fsync:
            dirfd = os.open(store_dir, os.O_RDONLY)
            try:
                os.fsync(dirfd)
            finally:
                os.close(dirfd)
        with self._lock:
            self.gauge.track(rows)
            self.ledger.write(arr.nbytes)
            self.ledger.rows_written += rows
            b = store_bucket(name)
            if b is not None:
                # Receive-side skew attribution: the inbox name encodes the
                # destination bucket, so every exchanged byte lands in the
                # per-bucket counters the rebalancer reads.
                self.ledger.bucket(b, arr.nbytes, rows)
            self.stats.frames_recv += 1
            self.stats.bytes_recv += arr.nbytes
        tracer = get_tracer()
        if tracer.enabled:
            tracer.instant(f"recv:{name}", cat="wire", bytes=int(arr.nbytes),
                           rows=rows)

    def _handle_migrate(self, meta: Dict, payload: bytes) -> None:
        rel = _check_rel_path(str(meta["path"]))
        offset, total = int(meta["offset"]), int(meta["total"])
        if offset < 0 or total < 0 or offset + len(payload) > total:
            raise TransportError(
                f"bad migration chunk bounds: offset={offset} "
                f"len={len(payload)} total={total}")
        if not payload and total > 0:
            raise TransportError(f"empty migration chunk for {rel!r}")
        final = os.path.join(self.workdir, *rel.split("/"))
        part = final + PART_SUFFIX
        os.makedirs(os.path.dirname(final), exist_ok=True)
        if offset == 0:
            f = open(part, "wb")      # (re)start: truncate any stale staging
        elif os.path.exists(part):
            f = open(part, "r+b")
        else:
            raise TransportError(
                f"migration chunk at offset {offset} without staged prefix "
                f"for {rel!r} — sender must restart the file")
        with f:
            f.seek(offset)
            if payload:
                f.write(payload)
            if self.fsync and offset + len(payload) >= total:
                f.flush()
                os.fsync(f.fileno())
        if offset + len(payload) >= total:
            os.replace(part, final)   # atomic: never a torn shard file
            if self.fsync:
                dirfd = os.open(os.path.dirname(final), os.O_RDONLY)
                try:
                    os.fsync(dirfd)
                finally:
                    os.close(dirfd)
        with self._lock:
            # Deliberately NOT bucket-attributed: migration bytes are
            # rebalancing overhead, and folding them into bucket_bytes would
            # make a just-moved bucket look hot at its new owner.
            self.ledger.write(len(payload))
            self.stats.migrate_frames += 1
            self.stats.migrate_bytes += len(payload)

    # -- accounting / lifecycle ----------------------------------------------
    def drain_accounting(self, ledger: IOLedger,
                         gauge: Optional[MemoryGauge] = None) -> TransportStats:
        """Move accumulated ledger counters into `ledger` (so the driver's
        per-phase deltas include receiver-side writes), fold the gauge peak,
        and hand over (then reset) the wire stats accumulated since the last
        drain."""
        with self._lock:
            ledger.merge(self.ledger.as_dict())
            self.ledger = IOLedger()
            if gauge is not None:
                gauge.track(self.gauge.peak_rows)
            out = self.stats
            self.stats = TransportStats()
            return out

    def stop(self) -> None:
        if self._stopping:
            return
        self._stopping = True
        try:
            self._sock.close()
        except OSError:
            pass
        self._accept_thread.join(timeout=5.0)
        # Unblock handler threads idling between frames (daemon threads, but
        # each pins a socket fd until its peer goes away).
        with self._lock:
            live = list(self._live_conns)
        for conn in live:
            try:
                conn.close()
            except OSError:
                pass

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.stop()


def make_transport(pcfg, workdir: str, ledger: IOLedger,
                   gauge: Optional[MemoryGauge] = None) -> Transport:
    """Build the transport a config asks for.  `pcfg` is duck-typed
    (GraphConfig or phases.PlainCfg): `transport` in {"fs", "socket"}, and for
    sockets `peer_addrs` must hold one live "host:port" per bucket — the
    partitioned driver starts loopback ExchangeServers and fills them in."""
    kind = getattr(pcfg, "transport", "fs")
    if kind in ("fs", "filesystem"):
        return FilesystemTransport(workdir, ledger, gauge)
    if kind == "socket":
        peers = getattr(pcfg, "peer_addrs", None)
        if not peers:
            raise ValueError(
                "transport='socket' needs peer_addrs (one ExchangeServer "
                "address per bucket) — use PartitionedGenerator, which "
                "starts loopback servers and plumbs their addresses through")
        return SocketTransport(workdir, ledger, gauge, peers=peers,
                               namespace=getattr(pcfg, "exchange_namespace",
                                                 None),
                               map_version=getattr(pcfg, "shard_map_version",
                                                   None))
    raise ValueError(f"unknown transport {kind!r} (expected 'fs' or 'socket')")
