"""Shared types and notation for the graph-generation core.

Mirrors the paper's §II preliminaries:

  n  = 2**scale          number of vertices
  m  = n * edge_factor   number of (directed) generated edges
  nb = number of "compute nodes" -> here: mesh shards
  B  = n / nb            bucket size (vertices per shard; range partition RP(n, nb))
  b  = B / nc            bin size (vertices per core) -> here: per-lane work, implicit
  mmc                    memory per core -> here: chunk_edges (device chunk) / VMEM tile
  C_e                    edges per disk block -> here: edges per host-store block

Vertex ownership (the paper's "a core owns the nodes in its partition range,
and the edges whose source is in its range"):

  owner(v) = v // B
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp

# Graph500 R-MAT parameters (Chakrabarti et al. 2004; Graph500 spec).
RMAT_A = 0.57
RMAT_B = 0.19
RMAT_C = 0.19
RMAT_D = 0.05
DEFAULT_EDGE_FACTOR = 16


@dataclasses.dataclass(frozen=True)
class GraphConfig:
    """Configuration for one graph-generation run (the paper's (n, f) inputs
    plus the machine-shape knobs the paper hard-codes in its MPI setup)."""

    scale: int = 16                       # n = 2**scale vertices
    edge_factor: int = DEFAULT_EDGE_FACTOR
    seed: int = 0x5EED_1234
    # R-MAT quadrant probabilities (a, b, c, d).
    a: float = RMAT_A
    b: float = RMAT_B
    c: float = RMAT_C
    d: float = RMAT_D
    # --- machine shape ---------------------------------------------------
    nb: int = 1                           # number of shards ("compute nodes")
    chunk_edges: int = 1 << 16            # mmc analogue: edges per in-memory chunk
    # --- static-shape adaptation ----------------------------------------
    # The paper's "send packet when full" becomes a fixed-capacity bucketed
    # all_to_all.  capacity_factor scales the per-destination buffer above
    # the uniform expectation to absorb R-MAT skew.
    capacity_factor: float = 2.0
    # --- algorithm variants ----------------------------------------------
    shuffle_rounds: int = 0               # 0 = auto = ceil(log_nb(n)) (paper)
    relabel_variant: str = "ring"         # "ring" (paper-faithful) | "alltoall" (optimized)
    csr_variant: str = "sorted"           # "sorted" (paper §III-B7) | "scatter" (paper Alg.10/11)
    vertex_dtype: jnp.dtype = jnp.int32
    # --- disk tier (core/external.py + core/phases.py) --------------------
    # "device": pv via the on-device shuffle, spilled to bucket files (holds
    #           pv in RAM once — the paper's §IV-A "artificial limitation").
    # "external": paper Alg. 2-4 on disk — pv built as nb bucket files via
    #           rounds of chunked local shuffle + bucket exchange; peak RSS
    #           stays O(chunk_edges) at any scale.
    # "recompute": communication-free (Funke et al.): the permutation is the
    #           keyed Feistel family (hostgen.graph_perm_np), so pv[u] is a
    #           pure hash of u — no pv store is materialized and relabel is a
    #           streaming map u -> perm(u) inline in the edge scan.  Zero
    #           exchange bytes; implies perm_family="feistel".
    shuffle_variant: str = "device"
    # Which permutation family defines the vertex relabeling:
    # "shuffle": the materialized shuffle-exchange permutation (paper).
    # "feistel": the keyed invertible Feistel family — recomputable anywhere,
    #           required (and auto-selected) by shuffle_variant="recompute",
    #           also legal with "external" (materializes the same pv through
    #           the store machinery; used by parity tests).  Needs scale <= 31
    #           (ids must fit the uint32 Feistel container).
    perm_family: str = "shuffle"
    # Feistel depth for perm_family="feistel"; even, >= 2.
    feistel_rounds: int = 4
    # Rows per cursor block in external merges; 0 = auto (one chunk of
    # memory split evenly across the merge fan-in).
    merge_block_rows: int = 0
    # Maximum merge fan-in (open run files / heap entries) of any external
    # merge.  Stores with more runs cascade through log-depth intermediate
    # merge passes (blockstore.merge_runs), bounding open files and keeping
    # per-cursor blocks at max_run/merge_fanin instead of max_run/num_runs —
    # the scale-safe default.  0 = flat (unbounded fan-in); must be >= 2
    # otherwise.
    merge_fanin: int = 64
    # Overlap disk I/O with compute in the external kernels: merge cursors
    # double-buffer their refills on a background prefetch thread
    # (blockstore.PrefetchReader) and run/partition emission completes
    # write-behind with one chunk in flight (blockstore.WriteBehindWriter).
    # Timing-only — outputs are bit-identical on vs. off, so the flag is
    # normalized out of result_config_key; at most doubles the resident
    # chunk bound (MemoryGauge-tracked).  Stall time lands in the IOLedger
    # read_wait_s/write_wait_s/overlap_s counters.  Env override:
    # REPRO_IO_OVERLAP=0 forces it off (CI serial shard).
    io_overlap: bool = True
    # Emit structured timing spans (core/trace.py) from every instrumented
    # layer — phase boundaries, kernel invocations, blockstore
    # sort/merge/partition, transport sends, I/O stall windows — into
    # per-process append-only `<workdir>/trace/trace_{pid}.jsonl` files,
    # mergeable into one Chrome/Perfetto timeline (`repro.launch.cluster
    # trace`).  Timing-only: outputs are bit-identical on vs. off, so the
    # flag is normalized out of result_config_key; emission buffers in
    # memory and flushes on a background thread (never blocks the traced
    # code).  Env override: REPRO_TRACE=1 forces it on, =0 off.
    trace: bool = False
    # Dispatch the partitioned CSR sort's cascade merge LEVELS through the
    # worker pool / cluster as (bucket, group) tasks instead of cascading
    # inline within each bucket's kernel (phases._run_csr_sorted_pooled).
    # Bit-identical output; changes the phase schedule, so checkpoints are
    # keyed on it.  Partitioned/cluster drivers only.
    pooled_cascade: bool = False
    # Persist per-phase output manifests to <workdir>/phases.json and resume
    # completed phases on re-run (PhaseOrchestrator).
    checkpoint_phases: bool = False
    # --- exchange transport (core/transport.py) ---------------------------
    # "fs":     bucket exchanges ride the shared filesystem via the
    #           {sender}_{seq} run-tag convention (single host, reference).
    # "socket": exchanges are framed TCP to per-bucket ExchangeServers —
    #           bytes cross the interconnect once instead of twice, and
    #           PartitionedGenerator workers can rendezvous across hosts.
    #           Outputs are bit-identical across backends.
    transport: str = "fs"
    # One "host:port" ExchangeServer address per bucket (socket transport).
    # None + transport="socket" lets PartitionedGenerator start loopback
    # servers and fill the addresses in.
    peer_addrs: Optional[Tuple[str, ...]] = None
    # Checkpoint GC escape hatch: True keeps every phase-output store on disk
    # for debugging; False (default) lets the PhaseOrchestrator drop a
    # phase's stores once all downstream consumers are done/checkpointed,
    # bounding the disk footprint.
    keep_phase_stores: bool = False

    # --- derived ----------------------------------------------------------
    @property
    def n(self) -> int:
        return 1 << self.scale

    @property
    def m(self) -> int:
        return self.n * self.edge_factor

    @property
    def bucket_size(self) -> int:
        """B = n / nb vertices per shard.  n is a power of two; require nb | n."""
        assert self.n % self.nb == 0, f"nb={self.nb} must divide n={self.n}"
        return self.n // self.nb

    @property
    def edges_per_shard(self) -> int:
        assert self.m % self.nb == 0
        return self.m // self.nb

    @property
    def rounds(self) -> int:
        """Number of shuffle rounds: the paper's log_nb(n) (Alg. 4 line 8)."""
        if self.shuffle_rounds > 0:
            return self.shuffle_rounds
        if self.nb <= 1:
            return 1
        import math

        return max(1, int(math.ceil(math.log(self.n) / math.log(self.nb))))

    def with_(self, **kw) -> "GraphConfig":
        return dataclasses.replace(self, **kw)


def owner_of(v: jnp.ndarray, bucket_size: int) -> jnp.ndarray:
    """Range-partition owner: owner(v) = v // B  (paper's RP(n, nb))."""
    return v // bucket_size


def quadrant_thresholds(cfg: GraphConfig) -> Tuple[int, int, int]:
    """Integer thresholds (on the uint32 lattice) for one R-MAT bit step.

    P(src_bit = 1)              = c + d
    P(dst_bit = 1 | src_bit=0)  = b / (a + b)
    P(dst_bit = 1 | src_bit=1)  = d / (c + d)

    Returned as uint32 cut points so the jnp reference and the Pallas kernel
    compare *identical integers* (bit-exact reproducibility across backends).
    """
    two32 = float(1 << 32)
    t_src = int((cfg.c + cfg.d) * two32)
    t_dst0 = int((cfg.b / (cfg.a + cfg.b)) * two32)
    t_dst1 = int((cfg.d / (cfg.c + cfg.d)) * two32)
    return t_src, t_dst0, t_dst1
