"""Sharded walk-corpus manifest — the multi-host collect format.

The single-workdir collect (one `walks.npy` memmap assembled on the driver)
cannot exist on a real cluster: no host's disk is required to hold the full
corpus.  Instead the collect phase leaves the corpus as **per-bucket shard
files** — bucket j's shard holds the walker block [w0, w1) that j's
history-gather kernel owns — plus one small JSON manifest describing them:

    {"version": 1, "num_walkers": W, "length": L, "dtype": "<i8",
     "shards": [{"bucket": 0, "w0": 0, "w1": 8, "path": "walks_b000.npy",
                 "host": 0}, ...]}

Shard paths are stored relative to the manifest's directory when the shard
lives under it (single-host layout: everything in one workdir, so a
checkpointed workdir can still be moved), absolute otherwise (cluster
layout: shards live in per-host workdirs the controller only references).

`ShardedWalks` is the read side: an array-like over the shard memmaps with
the same (shape, dtype, row indexing) surface the old monolithic memmap had,
so loaders and tests are corpus-layout-agnostic.  Walker blocks are the
uniform `ceil(W/nb)` blocks of phases.walker_block, which is what makes
row -> shard lookup a division instead of a search.

jax-free on purpose: worker processes and the cluster HostRunner import this
without paying a jax initialization.
"""

from __future__ import annotations

import json
import os
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np


def shard_name(out_name: str, bucket: int) -> str:
    """Per-bucket shard file name derived from the corpus name:
    walks.npy -> walks_b003.npy."""
    stem = out_name[:-4] if out_name.endswith(".npy") else out_name
    return f"{stem}_b{bucket:03d}.npy"


def manifest_name(out_name: str) -> str:
    stem = out_name[:-4] if out_name.endswith(".npy") else out_name
    return f"{stem}_manifest.json"


def write_manifest(path: str, num_walkers: int, length: int,
                   shards: Sequence[Dict], dtype=np.int64) -> str:
    """Atomically write a corpus manifest.  Each shard dict carries
    {bucket, w0, w1, path, host}; `path` is made manifest-relative when the
    shard lives under the manifest's directory."""
    base = os.path.dirname(os.path.abspath(path))
    norm = []
    for s in shards:
        p = os.path.abspath(s["path"])
        rel = os.path.relpath(p, base)
        norm.append({**s, "path": rel if not rel.startswith("..") else p})
    payload = {"version": 1, "num_walkers": int(num_walkers),
               "length": int(length), "dtype": np.dtype(dtype).str,
               "shards": norm}
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(payload, f, indent=1)
    os.replace(tmp, path)  # atomic: never a torn manifest
    return path


def read_manifest(path: str) -> Dict:
    with open(path) as f:
        m = json.load(f)
    if m.get("version") != 1:
        raise ValueError(f"unsupported corpus manifest version in {path}: "
                         f"{m.get('version')!r}")
    return m


class ShardedWalks:
    """Array-like view over a sharded walk corpus (read-only).

    shape [num_walkers, length + 1]; rows are walker histories.  Row w lives
    in shard w // wpb (uniform walker blocks), so `walks[wid_array]` is a
    grouped gather over at most nb shard memmaps — no shard is ever read
    whole unless asked for.  `np.asarray(walks)` materializes the full
    corpus (tests / small graphs only, exactly like concat_bucket_csr).
    """

    def __init__(self, manifest_path: str):
        self.manifest_path = os.path.abspath(manifest_path)
        m = read_manifest(self.manifest_path)
        base = os.path.dirname(self.manifest_path)
        self.num_walkers = int(m["num_walkers"])
        self.length = int(m["length"])
        self.dtype = np.dtype(m["dtype"])
        self.shards: List[Dict] = sorted(m["shards"], key=lambda s: s["w0"])
        for s in self.shards:
            if not os.path.isabs(s["path"]):
                s["path"] = os.path.join(base, s["path"])
        # Uniform block size (ceil(W/nb), the walker_block contract); the
        # last shard may be short or empty.
        self._wpb = (self.shards[0]["w1"] - self.shards[0]["w0"]
                     if self.shards else 0)
        self._mms: List[Optional[np.ndarray]] = [None] * len(self.shards)

    # -- array-like surface --------------------------------------------------
    @property
    def shape(self) -> Tuple[int, int]:
        return (self.num_walkers, self.length + 1)

    def __len__(self) -> int:
        return self.num_walkers

    def _mm(self, i: int) -> np.ndarray:
        if self._mms[i] is None:
            self._mms[i] = np.load(self.shards[i]["path"], mmap_mode="r")
        return self._mms[i]

    def __array__(self, dtype=None, copy=None):
        parts = [np.asarray(self._mm(i)) for i in range(len(self.shards))]
        out = (np.concatenate(parts) if parts
               else np.zeros((0, self.length + 1), self.dtype))
        return out.astype(dtype) if dtype is not None else out

    def rows(self, wid) -> np.ndarray:
        """Gather history rows for an int array of walker ids."""
        wid = np.asarray(wid, np.int64)
        if wid.size and (wid.min() < 0 or wid.max() >= self.num_walkers):
            raise IndexError(
                f"walker id out of range [0, {self.num_walkers})")
        out = np.empty((wid.shape[0], self.length + 1), self.dtype)
        if self._wpb == 0:
            return out
        shard_of = wid // self._wpb
        for i in np.unique(shard_of):
            sel = shard_of == i
            s = self.shards[int(i)]
            out[sel] = self._mm(int(i))[wid[sel] - s["w0"]]
        return out

    def __getitem__(self, key):
        if isinstance(key, (int, np.integer)):
            return self.rows(np.asarray([key]))[0]
        if isinstance(key, slice):
            return self.rows(np.arange(*key.indices(self.num_walkers)))
        return self.rows(key)
