"""Multi-tenant job queue over the cluster runtime.

PR 5's ClusterController drives exactly ONE graph at a time: every
HostRunner idles at every phase barrier while stragglers finish.  This
module layers a persistent job queue on the same rendezvous/control-frame
protocol: submit many (graph, corpus, config) jobs, decompose each into
the per-phase task keys HostRunner already checkpoints
(phases.phase_task_plan), and run several jobs' barrier loops concurrently
against one shared controller so hosts PULL work — bounded lease batches
from their own queue first, then STEAL migratable tasks from a busy peer's
queue tail.  One job's straggler never idles the fleet: the idle host
leases another job's tasks (independent jobs' I/O and exchange phases
overlap), and walk corpora submitted with `fuse_walks` batch every seed's
hop through one CSR scan per bucket (walk_hop_fused).

Isolation is by namespace: each job's exchange frames and host-side stores
live under the job tag's subdir (PlainCfg.exchange_namespace), so
concurrent jobs never share an inbox and a poisoned job's partials are one
rmtree to GC.  A task that fails deterministically past its lease budget
raises the job-scoped TaskError; the scheduler parks the job in the
DEAD-LETTER list (bulkhead: the bad job can't wedge the queue), cancels
its queued tasks, purges its namespace on every host, and keeps draining
the rest.  Every job's outputs are bit-identical to a serial single-job
run — the scheduler changes WHEN tasks run, never what they compute.

Queue state persists in <root>/jobqueue.json (atomic replace), so a
killed scheduler resumes: finished jobs stay done, a job caught mid-run
re-enters the queue and resumes from its per-host checkpoints.
"""

from __future__ import annotations

import dataclasses
import json
import os
import shutil
import threading
import time
from concurrent.futures import ThreadPoolExecutor
from typing import Dict, List, Optional, Sequence, Tuple

from .cluster import (
    ClusterController,
    ClusterGenerator,
    ClusterSpec,
    ExecBackend,
    TaskError,
    _pcfg_from_wire,
    _pcfg_to_wire,
)
from .phases import (
    PlainCfg,
    phase_task_plan,
    plain_config,
    validate_external_shape,
)
from .trace import TRACE_DIR

QUEUE_FILE = "jobqueue.json"


# ---------------------------------------------------------------------------
# JobSpec + persistent queue state
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class JobSpec:
    """One queued generation job: config, optional walk corpora, and the
    static task-key plan exported at submit time.  `tag` doubles as the
    job's exchange namespace and its workdir subdir on every host."""

    job_id: int
    cfg: Dict                                   # wire-form PlainCfg
    csr_variant: str = "sorted"
    walks: List[List] = dataclasses.field(default_factory=list)
    fuse_walks: bool = False
    fuse_gen_relabel: bool = False
    name: str = ""
    status: str = "queued"                      # queued|running|done|dead
    error: str = ""
    plan: List[Dict] = dataclasses.field(default_factory=list)

    @property
    def tag(self) -> str:
        return f"job{self.job_id:04d}"

    @property
    def num_tasks(self) -> int:
        return sum(len(p["keys"]) for p in self.plan)

    def to_json(self) -> Dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_json(cls, d: Dict) -> "JobSpec":
        return cls(**{f.name: d[f.name] for f in dataclasses.fields(cls)
                      if f.name in d})


def _state_path(root: str) -> str:
    return os.path.join(root, QUEUE_FILE)


def load_state(root: str) -> Dict:
    """Queue state: {"version", "next_id", "jobs", "dead_letters"}."""
    path = _state_path(root)
    if not os.path.exists(path):
        return {"version": 1, "next_id": 0, "jobs": [], "dead_letters": []}
    with open(path) as f:
        return json.load(f)


def save_state(root: str, state: Dict) -> str:
    os.makedirs(root, exist_ok=True)
    path = _state_path(root)
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(state, f, indent=1)
    os.replace(tmp, path)
    return path


def submit_job(root: str, cfg, csr_variant: str = "sorted",
               walks: Sequence[Tuple[int, int, int, str]] = (),
               fuse_walks: bool = False, fuse_gen_relabel: bool = False,
               name: str = "") -> JobSpec:
    """Append one job to <root>/jobqueue.json and return its JobSpec.  No
    controller needed — submission is a pure queue edit, so the CLI can
    enqueue while nothing is running (or while a drain is in flight on
    another box sharing the root).  The task-key plan is computed here,
    once: invalid configs (pooled_cascade, bad csr_variant, fuse without
    recompute) are rejected at submit time, not at dispatch."""
    pcfg = validate_external_shape(
        cfg if isinstance(cfg, PlainCfg) else plain_config(cfg))
    # Routing fields are dispatch-time state, never job identity: the
    # scheduler injects live peer_addrs and the current shard-map version
    # at every lease, so the stored cfg (and the task-key plan derived from
    # it) stays stable across rebalances.
    pcfg = dataclasses.replace(pcfg, transport="socket", peer_addrs=None,
                               exchange_namespace=None, shard_map_version=0)
    walks = [list(w) for w in walks]
    plan = phase_task_plan(pcfg, csr_variant=csr_variant,
                           walks=[tuple(w) for w in walks],
                           fuse_gen_relabel=fuse_gen_relabel,
                           fuse_walks=fuse_walks)
    state = load_state(root)
    job = JobSpec(job_id=int(state["next_id"]), cfg=_pcfg_to_wire(pcfg),
                  csr_variant=csr_variant, walks=walks,
                  fuse_walks=bool(fuse_walks),
                  fuse_gen_relabel=bool(fuse_gen_relabel),
                  name=name or f"scale{pcfg.scale}", plan=plan)
    state["next_id"] = job.job_id + 1
    state["jobs"].append(job.to_json())
    save_state(root, state)
    return job


# ---------------------------------------------------------------------------
# JobScheduler — concurrent drains over one shared controller
# ---------------------------------------------------------------------------


class JobScheduler:
    """Owns one ClusterController and drains the persistent queue through
    it: up to `max_concurrent` jobs run their phase-barrier loops on
    concurrent threads, so while job A waits on a straggler's barrier the
    hosts lease (or steal) job B's tasks.  `lease_size` bounds tasks per
    poll (small leases keep the tail stealable); `lease_budget` is the
    dispatch budget a deterministically failing task gets before its job
    dead-letters.

    Results per job land in <root>/<tag>/ on the controller and under the
    <tag>/ namespace subdir of every host workdir — bit-identical to
    running that job alone."""

    def __init__(self, spec: ClusterSpec, root: str,
                 backend: Optional[ExecBackend] = None,
                 max_concurrent: int = 2, lease_size: int = 2,
                 lease_budget: int = 2, heartbeat_timeout: float = 60.0,
                 max_restarts: int = 1, rendezvous_timeout: float = 120.0,
                 barrier_timeout: float = 600.0, checkpoint: bool = True,
                 advertise: Optional[str] = None):
        self.spec = spec
        self.root = root
        os.makedirs(root, exist_ok=True)
        self.max_concurrent = max(1, int(max_concurrent))
        self.lease_budget = max(1, int(lease_budget))
        self.barrier_timeout = barrier_timeout
        self.checkpoint = checkpoint
        self._state_lock = threading.Lock()
        self.state = load_state(root)
        self.makespan = 0.0
        # trace_dir is armed unconditionally: hosts only SHIP trace lines
        # when a traced job installed their tracer, so untraced queues never
        # create the directory.
        self.controller = ClusterController(
            spec, backend=backend, heartbeat_timeout=heartbeat_timeout,
            max_restarts=max_restarts, advertise=advertise,
            lease_size=lease_size,
            trace_dir=os.path.join(root, TRACE_DIR))
        try:
            self.controller.launch_hosts()
            self.controller.wait_for_hosts(rendezvous_timeout)
        except BaseException:
            self.controller.stop()
            raise

    # -- queue plumbing ------------------------------------------------------
    def submit(self, cfg, **kw) -> JobSpec:
        with self._state_lock:
            job = submit_job(self.root, cfg, **kw)
            self.state = load_state(self.root)
        return job

    def jobs(self) -> List[JobSpec]:
        with self._state_lock:
            return [JobSpec.from_json(d) for d in self.state["jobs"]]

    def _update(self, job: JobSpec, dead_letter: Optional[Dict] = None) -> None:
        with self._state_lock:
            for i, d in enumerate(self.state["jobs"]):
                if d["job_id"] == job.job_id:
                    self.state["jobs"][i] = job.to_json()
            if dead_letter is not None:
                self.state["dead_letters"].append(dead_letter)
            save_state(self.root, self.state)

    # -- execution -----------------------------------------------------------
    def _run_job(self, job: JobSpec) -> None:
        job.status = "running"
        self._update(job)
        gen = ClusterGenerator(
            _pcfg_from_wire(job.cfg), self.spec,
            workdir=os.path.join(self.root, job.tag),
            controller=self.controller, job=job.tag,
            checkpoint=self.checkpoint, barrier_timeout=self.barrier_timeout,
            lease_budget=self.lease_budget)
        dead_letter = None
        try:
            gen.run(csr_variant=job.csr_variant)
            specs = [tuple(w) for w in job.walks]
            if specs:
                if job.fuse_walks and len(specs) > 1:
                    gen.walk_corpus_fused(specs, checkpoint=self.checkpoint)
                else:
                    for (W, L, seed, out_name) in specs:
                        gen.walk_corpus(W, L, seed=seed, out_name=out_name,
                                        checkpoint=self.checkpoint)
            job.status = "done"
            job.error = ""
        except TaskError as e:
            # Poisoned task past its lease budget: dead-letter the JOB —
            # park it, cancel its queued tasks, GC its partial stores on
            # every host (one namespace rmtree) and on the controller —
            # and let every other job keep draining.
            dead_letter = {"job": job.tag, "task_key": e.task_key,
                           "attempts": e.attempts, "error": str(e)}
            job.status = "dead"
            job.error = str(e)
            self.controller.cancel_job(job.tag)
            try:
                gen.transport.purge_namespace()
            except Exception:
                pass   # a host died with the job; its relaunch re-sweeps
            shutil.rmtree(os.path.join(self.root, job.tag),
                          ignore_errors=True)
        finally:
            gen.close()    # transport only — the controller is shared
            self._update(job, dead_letter)

    def drain(self) -> Dict:
        """Run every queued job to done/dead, `max_concurrent` at a time,
        and return the fleet summary.  Jobs found 'running' (a killed
        scheduler) re-enter and resume from their checkpoints.  Utilization
        is busy-seconds summed over hosts divided by fleet-seconds of the
        drain — the number the work-stealing overlap is supposed to move."""
        todo = [j for j in self.jobs() if j.status in ("queued", "running")]
        with self.controller._lock:
            base_busy = dict(self.controller.busy_seconds)
        t0 = time.monotonic()
        if todo:
            with ThreadPoolExecutor(
                    max_workers=min(self.max_concurrent, len(todo)),
                    thread_name_prefix="jobq") as pool:
                futs = [pool.submit(self._run_job, j) for j in todo]
                for f in futs:
                    f.result()
        self.makespan = time.monotonic() - t0
        with self.controller._lock:
            busy = sum(v - base_busy.get(h, 0.0)
                       for h, v in self.controller.busy_seconds.items())
        fleet = self.spec.num_hosts * self.makespan
        self.state = load_state(self.root)
        summary = {
            "jobs": [{"job": j.tag, "name": j.name, "status": j.status,
                      "tasks": j.num_tasks} for j in self.jobs()],
            "makespan_s": self.makespan,
            "busy_s": busy,
            "utilization": (busy / fleet) if fleet > 0 else 0.0,
            "steals": self.controller.steals,
            "dead_letters": list(self.state["dead_letters"]),
        }
        return summary

    def close(self) -> None:
        self.controller.stop()

    def __enter__(self) -> "JobScheduler":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
