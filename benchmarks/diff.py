"""Benchmark trajectory diff: fail CI when the current BENCH_*.json set
regresses against the committed baseline.

Two families of numeric leaves are compared, each against its own
tolerance:

  wall time     every bench's `wall_seconds` in BENCH_summary.json, plus any
                leaf named `seconds`/`wall_seconds` inside a per-bench
                result tree.  Wall clocks are noisy, so leaves whose
                baseline is below `--min-wall` seconds are reported but
                never fail the diff.
  ledger bytes  every numeric leaf whose dotted path contains "bytes"
                (ledger_bytes, shuffle_wire_bytes, seq_reads' byte twins,
                ...).  These are deterministic accounting values — a
                regression here is a real I/O-complexity change, so the
                threshold applies at any magnitude above `--min-bytes`.

Forward-compat: subtrees named in IGNORED_SUBTREES ("meta" — run metadata
like git sha and hostname; "metrics" — the unified telemetry snapshot) are
skipped entirely, and any other unknown key yields at most a warning, so
BENCH json can grow new observability fields without breaking old
baselines.

A leaf regresses when  current > baseline * (1 + tol).  Leaves present only
in the baseline (bench removed / renamed) or only in the current run (new
bench) are warnings, not failures — the baseline is refreshed by copying
`experiments/bench/BENCH_*.json` over `benchmarks/baseline/` when a change
is intentional.

Usage (the CI step):

    PYTHONPATH=src python -m benchmarks.run --fast --only merge_fanin,...
    python benchmarks/diff.py --baseline benchmarks/baseline \
                              --current experiments/bench
"""

from __future__ import annotations

import argparse
import glob
import json
import os
import sys
from typing import Dict, Iterator, Tuple

WALL_KEYS = ("seconds", "wall_seconds")

# Observability subtrees that ride along in BENCH json but are not perf
# leaves: "meta" is per-run provenance (git sha, hostname, timestamp —
# different on every machine), "metrics" is the cumulative telemetry
# snapshot (trace.unified_snapshot), already covered by the deterministic
# result-tree byte leaves where it matters.  Skipped wholesale so the
# telemetry schema can evolve without churning baselines.
IGNORED_SUBTREES = ("meta", "metrics")


def _leaves(node, path: str = "") -> Iterator[Tuple[str, float]]:
    """Yield (dotted_path, value) for every numeric leaf of a JSON tree.
    List indices are path components so rows line up positionally."""
    if isinstance(node, dict):
        for k in sorted(node):
            if k in IGNORED_SUBTREES:
                continue
            yield from _leaves(node[k], f"{path}.{k}" if path else str(k))
    elif isinstance(node, list):
        for i, v in enumerate(node):
            yield from _leaves(v, f"{path}.{i}")
    elif isinstance(node, bool):
        return
    elif isinstance(node, (int, float)):
        yield path, float(node)


def _classify(path: str) -> str:
    last = path.rsplit(".", 1)[-1]
    if last in WALL_KEYS:
        return "wall"
    if "bytes" in last:
        return "bytes"
    return "other"


def load_tree(dirname: str) -> Dict[str, Dict[str, float]]:
    """{bench_name: {dotted_path: value}} over every BENCH_*.json in
    `dirname` (the summary file contributes under its own name)."""
    out: Dict[str, Dict[str, float]] = {}
    for path in sorted(glob.glob(os.path.join(dirname, "BENCH_*.json"))):
        name = os.path.basename(path)[len("BENCH_"):-len(".json")]
        try:
            with open(path) as f:
                payload = json.load(f)
        except (OSError, json.JSONDecodeError) as e:
            print(f"[diff] WARNING: unreadable {path}: {e}")
            continue
        out[name] = {p: v for p, v in _leaves(payload)
                     if _classify(p) != "other"}
    return out


def compare(baseline: Dict[str, Dict[str, float]],
            current: Dict[str, Dict[str, float]],
            wall_tol: float, bytes_tol: float,
            min_wall: float, min_bytes: float) -> Tuple[list, list]:
    """Returns (failures, warnings) as printable strings."""
    failures, warnings = [], []
    for bench in sorted(set(baseline) | set(current)):
        if bench not in current:
            warnings.append(f"bench '{bench}' in baseline but not in current "
                            "run (removed or not selected)")
            continue
        if bench not in baseline:
            warnings.append(f"bench '{bench}' is new (no baseline); copy "
                            "experiments/bench over benchmarks/baseline to "
                            "track it")
            continue
        base, cur = baseline[bench], current[bench]
        for path in sorted(set(base) | set(cur)):
            if path not in cur:
                warnings.append(f"{bench}:{path} disappeared")
                continue
            if path not in base:
                warnings.append(f"{bench}:{path} is new")
                continue
            b, c = base[path], cur[path]
            kind = _classify(path)
            tol = wall_tol if kind == "wall" else bytes_tol
            if b <= 0:
                if c > 0 and kind == "bytes":
                    failures.append(f"{bench}:{path} grew from 0 to {c:g}")
                continue
            ratio = c / b
            line = (f"{bench}:{path} {b:g} -> {c:g} "
                    f"({(ratio - 1) * 100:+.1f}%)")
            if ratio > 1 + tol:
                if kind == "wall" and b < min_wall:
                    warnings.append(line + " [below --min-wall, not failing]")
                elif kind == "bytes" and b < min_bytes:
                    warnings.append(line + " [below --min-bytes, not failing]")
                else:
                    failures.append(line)
    return failures, warnings


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--baseline", default="benchmarks/baseline")
    ap.add_argument("--current", default="experiments/bench")
    ap.add_argument("--wall-tol", type=float, default=0.20,
                    help="fail when wall time grows past baseline*(1+tol)")
    ap.add_argument("--bytes-tol", type=float, default=0.20,
                    help="fail when a *bytes* leaf grows past baseline*(1+tol)")
    ap.add_argument("--min-wall", type=float, default=1.0,
                    help="wall leaves with baseline below this many seconds "
                         "warn instead of fail (clock noise floor)")
    ap.add_argument("--min-bytes", type=float, default=4096,
                    help="bytes leaves with baseline below this warn instead "
                         "of fail")
    args = ap.parse_args(argv)

    if not os.path.isdir(args.baseline) or not glob.glob(
            os.path.join(args.baseline, "BENCH_*.json")):
        print(f"[diff] no baseline at {args.baseline}; nothing to compare "
              "(seed it by copying experiments/bench/BENCH_*.json there)")
        return 0
    baseline = load_tree(args.baseline)
    current = load_tree(args.current)
    if not current:
        print(f"[diff] FAIL: no BENCH_*.json under {args.current} — did the "
              "benchmark step run?")
        return 1
    failures, warnings = compare(baseline, current, args.wall_tol,
                                 args.bytes_tol, args.min_wall, args.min_bytes)
    for w in warnings:
        print(f"[diff] warn: {w}")
    for f_ in failures:
        print(f"[diff] FAIL: {f_}")
    if failures:
        print(f"[diff] {len(failures)} regression(s) vs {args.baseline}")
        return 1
    print(f"[diff] ok: no regressions vs {args.baseline} "
          f"({sum(len(v) for v in current.values())} leaves checked, "
          f"{len(warnings)} warning(s))")
    return 0


if __name__ == "__main__":
    sys.exit(main())
