"""The external shuffle (paper Alg. 2-4 on disk) vs the device-spill path.

Four measurements:

  memory   MemoryGauge peak resident rows across scales at fixed chunk_edges
           — the paper's claim: the external shuffle's working set does NOT
           grow with n, while the device-spill path holds pv once (the
           §IV-A "artificial limitation on the shuffle").
  io       per-phase I/O-ledger deltas for the external variant: the shuffle
           must be purely sequential (rand_reads == rand_writes == 0).
  workers  wall time of the multi-process partitioned mode vs the
           single-process streaming driver at the same config (the
           single-host stand-in for the paper's strong scaling, Fig. 3).
  recompute  the communication-free permutation (keyed Feistel family) vs
           the materialized external shuffle at the same seed: wall time,
           total IOLedger bytes, hash evaluations, and wire bytes split into
           the shuffle phases (ZERO for recompute — there are none) vs the
           whole run.  CSR bucket files are asserted bit-identical across
           the variants before the row is reported.
"""

from __future__ import annotations

import hashlib
import os
import tempfile
import time

from repro.core.external import StreamingGenerator
from repro.core.phases import PartitionedGenerator, csr_adjv_path, csr_offv_path
from repro.core.types import GraphConfig

from .common import print_table, save_json


def _csr_digest(workdir: str, nb: int) -> str:
    h = hashlib.sha256()
    for i in range(nb):
        for p in (csr_offv_path(workdir, i), csr_adjv_path(workdir, i)):
            with open(p, "rb") as f:
                h.update(f.read())
    return h.hexdigest()


def _recompute_row(label: str, cfg: GraphConfig, workers: int = 0):
    with tempfile.TemporaryDirectory() as d:
        t0 = time.perf_counter()
        with PartitionedGenerator(cfg, d, max_workers=workers) as part:
            part.run()
            secs = time.perf_counter() - t0
            rep = part.orchestrator.report()
            led = part.ledger
            digest = _csr_digest(d, cfg.nb)
    shuffle_wire = sum(int(p.get("wire_bytes_sent", 0)) for p in rep
                       if p["phase"].startswith("shuffle"))
    total_wire = sum(int(p.get("wire_bytes_sent", 0)) for p in rep)
    return {"variant": label, "seconds": secs,
            "ledger_bytes": led.bytes_read + led.bytes_written,
            "hash_evals": led.hash_evals,
            "shuffle_wire_bytes": shuffle_wire,
            "total_wire_bytes": total_wire,
            "csr_sha256": digest}


def run(scales=(10, 12, 14), chunk=1 << 10, nb=4, worker_counts=(0, 2, 4)):
    mem_rows = []
    for s in scales:
        row = {"scale": s, "n": 1 << s}
        for variant in ("device", "external"):
            cfg = GraphConfig(scale=s, nb=nb, chunk_edges=chunk,
                              shuffle_variant=variant, edge_factor=4)
            with tempfile.TemporaryDirectory() as d:
                gen = StreamingGenerator(cfg, d)
                gen.orchestrator.run_phase("shuffle", gen.permutation)
                row[f"peak_{variant}"] = gen.gauge.peak_rows
        mem_rows.append(row)
    print_table("shuffle peak resident rows (fixed chunk_edges=%d)" % chunk,
                mem_rows, ["scale", "n", "peak_device", "peak_external"])

    cfg = GraphConfig(scale=scales[-1], nb=nb, chunk_edges=chunk,
                      shuffle_variant="external", edge_factor=4)
    with tempfile.TemporaryDirectory() as d:
        gen = StreamingGenerator(cfg, d)
        gen.run()
        io_rows = gen.orchestrator.report()
    print_table("external variant, per-phase ledger deltas",
                io_rows, ["phase", "seconds", "seq_reads", "seq_writes",
                          "rand_reads", "rand_writes"])

    worker_rows = []
    wcfg = GraphConfig(scale=scales[0], nb=nb, chunk_edges=chunk,
                       shuffle_variant="external", edge_factor=4)
    for w in worker_counts:
        with tempfile.TemporaryDirectory() as d:
            t0 = time.perf_counter()
            with PartitionedGenerator(wcfg, d, max_workers=w) as part:
                part.run()
            worker_rows.append({"workers": w or "in-proc",
                                "seconds": time.perf_counter() - t0})
    print_table("partitioned mode wall time (scale=%d, nb=%d)" % (scales[0], nb),
                worker_rows, ["workers", "seconds"])

    recompute_rows = []
    for label, variant, perm in (("external/shuffle", "external", "shuffle"),
                                 ("external/feistel", "external", "feistel"),
                                 ("recompute", "recompute", "feistel")):
        rcfg = GraphConfig(scale=scales[-1], nb=nb, chunk_edges=chunk,
                           shuffle_variant=variant, perm_family=perm,
                           edge_factor=4)
        recompute_rows.append(_recompute_row(label, rcfg))
    # The tentpole's contract: same seed + feistel family => bit-identical
    # CSR bucket files whether the permutation was materialized (external)
    # or recomputed in-stream.
    assert (recompute_rows[1]["csr_sha256"] == recompute_rows[2]["csr_sha256"]), \
        "recompute CSR diverged from external+feistel"
    print_table("recompute vs external (scale=%d, nb=%d)" % (scales[-1], nb),
                recompute_rows,
                ["variant", "seconds", "ledger_bytes", "hash_evals",
                 "shuffle_wire_bytes", "total_wire_bytes"])

    save_json("external_shuffle",
              {"memory": mem_rows, "per_phase_io": io_rows,
               "workers": worker_rows, "recompute": recompute_rows})
    return {"memory": mem_rows, "per_phase_io": io_rows,
            "workers": worker_rows, "recompute": recompute_rows}


if __name__ == "__main__":
    run()
