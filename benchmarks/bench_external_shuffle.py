"""The external shuffle (paper Alg. 2-4 on disk) vs the device-spill path.

Three measurements:

  memory   MemoryGauge peak resident rows across scales at fixed chunk_edges
           — the paper's claim: the external shuffle's working set does NOT
           grow with n, while the device-spill path holds pv once (the
           §IV-A "artificial limitation on the shuffle").
  io       per-phase I/O-ledger deltas for the external variant: the shuffle
           must be purely sequential (rand_reads == rand_writes == 0).
  workers  wall time of the multi-process partitioned mode vs the
           single-process streaming driver at the same config (the
           single-host stand-in for the paper's strong scaling, Fig. 3).
"""

from __future__ import annotations

import tempfile
import time

from repro.core.external import StreamingGenerator
from repro.core.phases import PartitionedGenerator
from repro.core.types import GraphConfig

from .common import print_table, save_json


def run(scales=(10, 12, 14), chunk=1 << 10, nb=4, worker_counts=(0, 2, 4)):
    mem_rows = []
    for s in scales:
        row = {"scale": s, "n": 1 << s}
        for variant in ("device", "external"):
            cfg = GraphConfig(scale=s, nb=nb, chunk_edges=chunk,
                              shuffle_variant=variant, edge_factor=4)
            with tempfile.TemporaryDirectory() as d:
                gen = StreamingGenerator(cfg, d)
                gen.orchestrator.run_phase("shuffle", gen.permutation)
                row[f"peak_{variant}"] = gen.gauge.peak_rows
        mem_rows.append(row)
    print_table("shuffle peak resident rows (fixed chunk_edges=%d)" % chunk,
                mem_rows, ["scale", "n", "peak_device", "peak_external"])

    cfg = GraphConfig(scale=scales[-1], nb=nb, chunk_edges=chunk,
                      shuffle_variant="external", edge_factor=4)
    with tempfile.TemporaryDirectory() as d:
        gen = StreamingGenerator(cfg, d)
        gen.run()
        io_rows = gen.orchestrator.report()
    print_table("external variant, per-phase ledger deltas",
                io_rows, ["phase", "seconds", "seq_reads", "seq_writes",
                          "rand_reads", "rand_writes"])

    worker_rows = []
    wcfg = GraphConfig(scale=scales[0], nb=nb, chunk_edges=chunk,
                       shuffle_variant="external", edge_factor=4)
    for w in worker_counts:
        with tempfile.TemporaryDirectory() as d:
            t0 = time.perf_counter()
            with PartitionedGenerator(wcfg, d, max_workers=w) as part:
                part.run()
            worker_rows.append({"workers": w or "in-proc",
                                "seconds": time.perf_counter() - t0})
    print_table("partitioned mode wall time (scale=%d, nb=%d)" % (scales[0], nb),
                worker_rows, ["workers", "seconds"])

    save_json("external_shuffle",
              {"memory": mem_rows, "per_phase_io": io_rows, "workers": worker_rows})
    return mem_rows, io_rows, worker_rows


if __name__ == "__main__":
    run()
