"""Paper Fig. 5: weak scaling of relabel + redistribute — problem size and
shard count grow together (scale s with nb = 2^(s - s0) shards), so the
per-shard work is constant.  The paper finds these two phases scale
SUB-linearly: relabel because every shard scans the whole permutation
vector, redistribute because R-MAT degree skew concentrates edges on a few
owners.  Both effects reproduce here (the skew one shows up as rising
capacity-driven padding)."""

from __future__ import annotations

import json
import os
import subprocess
import sys

from .common import print_table, save_json

_CHILD = r"""
import os, sys, json, time
import jax
from repro.core.types import GraphConfig
from repro.core.pipeline import generate_edges
from repro.core.shuffle import distributed_shuffle
from repro.core.relabel import relabel_ring
from repro.core.redistribute import redistribute_sorted

scale, nb = int(sys.argv[1]), int(sys.argv[2])
cfg = GraphConfig(scale=scale, nb=nb, capacity_factor=4.0)
from repro.distributed.collectives import flat_mesh
mesh = flat_mesh(nb)

def t(fn):
    jax.block_until_ready(fn())
    best = 1e9
    for _ in range(3):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        best = min(best, time.perf_counter() - t0)
    return best

pv = distributed_shuffle(cfg, mesh)
src, dst = generate_edges(cfg, mesh)
res = {}
res["relabel"] = t(lambda: relabel_ring(cfg, mesh, src, dst, pv))
ns, nd = relabel_ring(cfg, mesh, src, dst, pv)
res["redistribute"] = t(lambda: redistribute_sorted(cfg, mesh, ns, nd))
print("RESULT " + json.dumps(res))
"""


def run(base_scale=10, steps=4):
    rows = []
    for i in range(steps):
        s, nb = base_scale + i, 1 << i
        env = dict(os.environ,
                   XLA_FLAGS=f"--xla_force_host_platform_device_count={nb}",
                   PYTHONPATH="src")
        r = subprocess.run([sys.executable, "-c", _CHILD, str(s), str(nb)],
                           env=env, capture_output=True, text=True, timeout=1200)
        assert r.returncode == 0, r.stderr[-2000:]
        line = [l for l in r.stdout.splitlines() if l.startswith("RESULT ")][0]
        res = json.loads(line[len("RESULT "):])
        rows.append({"(s, nb)": f"({s},{nb})", **res})
    print_table("Fig.5: weak scaling of relabel/redistribute [s]",
                rows, ["(s, nb)", "relabel", "redistribute"])
    save_json("weak_scaling", rows)
    return rows


if __name__ == "__main__":
    run()
