"""Deliverable (g) reporting: render the roofline table from the dry-run
records (experiments/dryrun_baseline.jsonl), one row per (arch x shape x
mesh) cell.  The dry-run itself is `python -m repro.launch.dryrun`; this
benchmark only reads its output so `python -m benchmarks.run` stays fast."""

from __future__ import annotations

import json
import os

from .common import print_table, save_json

BASELINE = "experiments/dryrun_final.jsonl"


def run(path=BASELINE):
    if not os.path.exists(path):
        print(f"[bench_roofline] {path} missing — run "
              f"`PYTHONPATH=src python -m repro.launch.dryrun --out {path}`")
        return []
    recs = [json.loads(l) for l in open(path)]
    ok = [r for r in recs if r["status"] == "ok"]
    rows = []
    for r in sorted(ok, key=lambda r: (r["arch"], r["shape"], r["mesh"])):
        ro = r["roofline"]
        rows.append({
            "arch": r["arch"][:20], "shape": r["shape"], "mesh": r["mesh"],
            "t_compute": ro["t_compute_s"], "t_memory": ro["t_memory_s"],
            "t_coll": ro["t_collective_s"], "bound": ro["bottleneck"][:4],
            "useful": ro["useful_flops_ratio"], "mfu_bound": ro["mfu_bound"],
        })
    print_table("Roofline terms per dry-run cell (from compiled HLO)",
                rows, ["arch", "shape", "mesh", "t_compute", "t_memory",
                       "t_coll", "bound", "useful", "mfu_bound"])
    n_skip = sum(r["status"] == "skipped" for r in recs)
    print(f"[{len(ok)} cells ok, {n_skip} documented skips]")
    save_json("roofline_table", rows)
    return rows


if __name__ == "__main__":
    run()
