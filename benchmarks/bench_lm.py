"""LM-side throughput microbenchmarks on CPU smoke configs: train step
tokens/s and engine decode tokens/s.  Not a paper figure — the harness's
sanity meter that the training/serving substrate is real and runs."""

from __future__ import annotations

import dataclasses

import jax
import numpy as np

from repro.configs.base import SHAPES, get_smoke_config
from repro.models.registry import init_all, input_specs
from repro.serve import Engine, Request
from repro.train import OptimConfig, init_state, make_train_step

from .common import print_table, save_json, time_fn


def run(archs=("internlm2-1.8b", "mamba2-780m", "qwen3-moe-235b-a22b")):
    rows = []
    small = dataclasses.replace(SHAPES["train_4k"], seq_len=64, global_batch=4)
    for arch in archs:
        cfg = get_smoke_config(arch)
        ocfg = OptimConfig()
        state, _ = init_state(cfg, ocfg)
        batch = input_specs(cfg, small, mode="init")
        fn = jax.jit(make_train_step(cfg, ocfg, None))
        state, _ = fn(state, batch)  # compile
        t = time_fn(lambda: fn(state, batch), repeats=3)
        toks = small.seq_len * small.global_batch
        rows.append({"arch": arch, "train_ms": t * 1e3,
                     "train_tok_s": toks / t})
    print_table("LM train-step throughput (smoke configs, CPU)",
                rows, ["arch", "train_ms", "train_tok_s"])

    srows = []
    for arch in ("internlm2-1.8b", "mamba2-780m"):
        cfg = get_smoke_config(arch)
        params, _ = init_all(cfg)
        eng = Engine(cfg, params, max_batch=4, max_len=64)
        rng = np.random.default_rng(0)
        reqs = [Request(uid=i, prompt=rng.integers(0, cfg.vocab_size, 4).tolist(),
                        max_new_tokens=8) for i in range(8)]
        import time
        t0 = time.perf_counter()
        eng.run(reqs)
        dt = time.perf_counter() - t0
        srows.append({"arch": arch, "decode_tok_s": eng.decode_tokens / dt,
                      "engine_steps": eng.steps})
    print_table("Engine decode throughput (smoke configs, CPU)",
                srows, ["arch", "decode_tok_s", "engine_steps"])
    save_json("lm_throughput", {"train": rows, "serve": srows})
    return rows, srows


if __name__ == "__main__":
    run()
