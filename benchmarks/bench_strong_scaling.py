"""Paper Fig. 3/4: strong scaling — fixed problem size, growing shard count.

Each shard count runs in a SUBPROCESS (XLA pins the device count at init),
generating the same graph on nb = 1, 2, 4, 8 fake devices and timing the
total + per-phase cost.  The paper's observation that small scales stop
scaling early (scale-16 saturates at 2 nodes) reproduces as fixed per-shard
overheads dominating."""

from __future__ import annotations

import json
import os
import subprocess
import sys

from .common import print_table, save_json

_CHILD = r"""
import os, sys, json, time
import jax
from repro.core.types import GraphConfig
from repro.core.pipeline import generate_edges
from repro.core.shuffle import distributed_shuffle
from repro.core.relabel import relabel_ring
from repro.core.redistribute import redistribute_sorted
from repro.core.csr import build_csr_sorted
from repro.distributed.collectives import flat_mesh

scale, nb = int(sys.argv[1]), int(sys.argv[2])
cfg = GraphConfig(scale=scale, nb=nb, capacity_factor=4.0)
mesh = flat_mesh(nb)

def t(fn):
    fn_out = fn()
    jax.block_until_ready(fn_out)   # includes compile; then time warm
    best = 1e9
    for _ in range(3):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        best = min(best, time.perf_counter() - t0)
    return best

res = {}
res["shuffle"] = t(lambda: distributed_shuffle(cfg, mesh))
pv = distributed_shuffle(cfg, mesh)
res["edge_gen"] = t(lambda: generate_edges(cfg, mesh))
src, dst = generate_edges(cfg, mesh)
res["relabel"] = t(lambda: relabel_ring(cfg, mesh, src, dst, pv))
ns, nd = relabel_ring(cfg, mesh, src, dst, pv)
res["redistribute"] = t(lambda: redistribute_sorted(cfg, mesh, ns, nd))
owned = redistribute_sorted(cfg, mesh, ns, nd)
res["csr"] = t(lambda: build_csr_sorted(cfg, mesh, owned))
res["total"] = sum(res.values())
print("RESULT " + json.dumps(res))
"""


def run(scales=(12, 14), shard_counts=(1, 2, 4, 8)):
    rows = []
    for s in scales:
        for nb in shard_counts:
            env = dict(os.environ,
                       XLA_FLAGS=f"--xla_force_host_platform_device_count={nb}",
                       PYTHONPATH="src")
            r = subprocess.run([sys.executable, "-c", _CHILD, str(s), str(nb)],
                               env=env, capture_output=True, text=True,
                               timeout=1200)
            assert r.returncode == 0, r.stderr[-2000:]
            line = [l for l in r.stdout.splitlines() if l.startswith("RESULT ")][0]
            res = json.loads(line[len("RESULT "):])
            norm = 2.0 ** (s - 16)
            rows.append({"scale": s, "nb": nb,
                         **{k: v / norm for k, v in res.items()}})
    print_table("Fig.3/4: strong scaling, per-phase time / 2^(s-16) [s]",
                rows, ["scale", "nb", "total", "shuffle", "edge_gen",
                       "relabel", "redistribute", "csr"])
    save_json("strong_scaling", rows)
    return rows


if __name__ == "__main__":
    run()
