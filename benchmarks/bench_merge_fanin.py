"""Fan-in sweep of the bounded-fan-in cascaded external merge.

The trade the cascade makes (STXXL-style log-depth multiway merge): each
level below num_runs costs one extra sequential read+write pass over the
data, but bounds the open-file count and merge heap at max_fanin and keeps
per-cursor blocks at max_run/max_fanin instead of max_run/num_runs.  The
sweep reports, per fan-in:

  levels       cascade depth (0 = flat single-pass merge)
  bytes_read/  total ledger traffic — grows ~linearly with levels, the
  bytes_written  pass-count x bytes trade-off of ISSUE 3 / Hamann et al.
  seq_reads    block-granular read count: the flat merge's tiny per-cursor
               blocks explode this at high fan-in, the cascade's stay chunky
  peak_rows    MemoryGauge high-water mark (cursor buffers + flush block)
  open_runs    worst-case simultaneously-open run files (= merge fan-in)
  seconds      wall time

Every sweep point is checksummed against the flat merge — bit-identical
output is asserted, not assumed.
"""

from __future__ import annotations

import hashlib
import math
import tempfile
import time

import numpy as np

from repro.core.blockstore import BlockStore, IOLedger, MemoryGauge, merge_runs

from .common import print_table, save_json


def _build(workdir: str, nruns: int, run_rows: int) -> None:
    ledger = IOLedger()
    store = BlockStore(workdir, "runs", ledger, columns=("k", "p"))
    rng = np.random.default_rng(7)
    for i in range(nruns):
        k = np.sort(rng.integers(0, 1 << 40, run_rows))
        store.append_run(k, i * run_rows + np.arange(run_rows))


def _merge_once(workdir: str, fanin: int):
    ledger, gauge = IOLedger(), MemoryGauge()
    store = BlockStore.attach(workdir, "runs", ledger,
                              columns=("k", "p"), gauge=gauge)
    # One digest per column: output block BOUNDARIES legitimately differ
    # across fan-ins (flush sizes track cursor blocks), only the per-column
    # record streams must match bit for bit.
    digests = [hashlib.sha256() for _ in store.columns]
    t0 = time.perf_counter()
    rows = 0
    for cols in merge_runs(store, key=0, max_fanin=fanin):
        rows += cols[0].shape[0]
        for dg, c in zip(digests, cols):
            dg.update(np.ascontiguousarray(c).tobytes())
    return {
        "seconds": round(time.perf_counter() - t0, 4),
        "rows": rows,
        "seq_reads": ledger.seq_reads,
        "bytes_read": ledger.bytes_read,
        "bytes_written": ledger.bytes_written,
        "peak_rows": gauge.peak_rows,
    }, tuple(dg.hexdigest() for dg in digests)


def run(nruns=512, run_rows=2048, fanins=(0, 4, 8, 16, 64, 256)):
    rows = []
    ref_digest = None
    with tempfile.TemporaryDirectory() as d:
        _build(d, nruns, run_rows)
        for fanin in fanins:
            stats, digest = _merge_once(d, fanin)
            if ref_digest is None:
                ref_digest = digest  # fanins[0] should be 0 = flat reference
            assert digest == ref_digest, (
                f"cascade at max_fanin={fanin} is NOT bit-identical to flat")
            levels = (0 if fanin == 0 or nruns <= fanin
                      else int(math.ceil(math.log(nruns) / math.log(fanin))) - 1)
            rows.append({
                "max_fanin": fanin or "flat",
                "levels": levels,
                "open_runs": min(fanin, nruns) if fanin else nruns,
                **stats,
                "identical": True,
            })
    print_table(
        "cascaded merge fan-in sweep (nruns=%d, run_rows=%d)" % (nruns, run_rows),
        rows, ["max_fanin", "levels", "open_runs", "seconds", "seq_reads",
               "bytes_read", "bytes_written", "peak_rows", "identical"])
    save_json("merge_fanin", {"nruns": nruns, "run_rows": run_rows,
                              "sweep": rows})
    return rows


if __name__ == "__main__":
    run()
