"""Paper Fig. 2: per-phase time on a single compute node, normalized by
2^(s-16), across scales.  Flat curves = linear scaling in problem size; the
paper's scatter-CSR curve grows super-linearly — ours shows the same on the
scatter variant and stays flat on the sorted variant (§III-B7, which the
paper proposed but did not implement)."""

from __future__ import annotations

import jax.numpy as jnp

from repro.core.csr import build_csr_scatter, build_csr_sorted
from repro.core.pipeline import generate_edges
from repro.core.redistribute import redistribute, redistribute_sorted
from repro.core.relabel import relabel_ring
from repro.core.shuffle import distributed_shuffle
from repro.core.types import GraphConfig
from repro.distributed.collectives import flat_mesh

from .common import normalized, print_table, save_json, time_fn


def run(scales=(10, 12, 14, 16), base=16):
    mesh = flat_mesh(1)
    rows = []
    for s in scales:
        cfg = GraphConfig(scale=s, nb=1, capacity_factor=3.0)
        t_shuffle = time_fn(lambda: distributed_shuffle(cfg, mesh))
        pv = distributed_shuffle(cfg, mesh)
        t_gen = time_fn(lambda: generate_edges(cfg, mesh))
        src, dst = generate_edges(cfg, mesh)
        t_rel = time_fn(lambda: relabel_ring(cfg, mesh, src, dst, pv))
        nsrc, ndst = relabel_ring(cfg, mesh, src, dst, pv)
        t_red_s = time_fn(lambda: redistribute_sorted(cfg, mesh, nsrc, ndst))
        owned_s = redistribute_sorted(cfg, mesh, nsrc, ndst)
        owned_u = redistribute(cfg, mesh, nsrc, ndst)
        t_csr_sorted = time_fn(lambda: build_csr_sorted(cfg, mesh, owned_s))
        t_csr_scatter = time_fn(lambda: build_csr_scatter(cfg, mesh, owned_u))
        rows.append({
            "scale": s,
            "shuffle": normalized(t_shuffle, s, base),
            "edge_gen": normalized(t_gen, s, base),
            "relabel": normalized(t_rel, s, base),
            "redistribute": normalized(t_red_s, s, base),
            "csr_sorted": normalized(t_csr_sorted, s, base),
            "csr_scatter": normalized(t_csr_scatter, s, base),
        })
    print_table("Fig.2: single-node per-phase time / 2^(s-16) [s]",
                rows, ["scale", "shuffle", "edge_gen", "relabel",
                       "redistribute", "csr_sorted", "csr_scatter"])
    save_json("single_node", rows)
    return rows


if __name__ == "__main__":
    run()
