"""Overlapped vs serial I/O on an I/O-bound merge cascade (ISSUE 9 gate).

The tentpole claim — effective pass cost drops from R + C + W toward
max(R, C, W) when merge-cursor refills prefetch on a background thread and
run emission completes write-behind (external.py's overlap term) — is easy
to assert on a RAM-backed tmpdir only if the I/O is *made* slow.  A
ThrottledLedger subclasses IOLedger and sleeps a per-byte toll inside
read()/write(), i.e. on whatever thread performs the transfer: serial runs
pay the toll inline on the consumer thread; overlapped runs pay it on the
prefetch/write-behind threads where it hides behind the merge compute.
The toll is deterministic (pure f(bytes)), so the win is a property of the
pipeline structure, not of disk cache luck — and the same blocks move in
both modes (bit-identity asserted per column, sha256).

Reported per point:

  serial_s / overlap_s   wall time of the cascaded merge + re-emit
  speedup                serial_s / overlap_s  (gate: > 1.0, strictly)
  read_wait_s            consumer time blocked on an unfinished prefetch
  write_wait_s           producer time blocked on the in-flight chunk
  hidden_s               ledger.overlap_s — I/O seconds hidden behind compute
  overlap_frac           hidden_s / (hidden_s + waits) — measured fraction

The gate asserts overlapped wall time strictly beats serial AND the streams
are bit-identical; baseline/BENCH_overlap.json pins the trajectory.
"""

from __future__ import annotations

import hashlib
import tempfile
import time

import numpy as np

from repro.core.blockstore import (BlockStore, IOLedger, MemoryGauge,
                                   merge_runs, write_behind)

from .common import print_table, save_json

# Per-byte sleep toll: tuned so the I/O term (~1 s per direction at the
# default point) clearly DOMINATES the Python merge compute — the serial
# R + C + W vs overlapped max(R, C, W) gap must stay wide enough to
# survive a loaded CI machine.  The default point's refill blocks
# (run_rows / fanin rows) and emit chunks must sit ABOVE
# blockstore._ASYNC_IO_MIN_BYTES, or the async layer (rightly) declines
# to engage on them and the gate measures nothing.
_TOLL_S_PER_MB = 0.25


class ThrottledLedger(IOLedger):
    """IOLedger that charges a deterministic time toll per byte moved, ON
    THE CALLING THREAD, before taking the ledger lock — the tmpdir-backed
    store gets the latency profile of a real disk, and the toll lands
    exactly where the transfer runs (consumer thread when serial, I/O
    thread when overlapped)."""

    def read(self, nbytes: int, sequential: bool = True) -> None:
        time.sleep(nbytes * _TOLL_S_PER_MB / (1 << 20))
        super().read(nbytes, sequential)

    def write(self, nbytes: int, sequential: bool = True) -> None:
        time.sleep(nbytes * _TOLL_S_PER_MB / (1 << 20))
        super().write(nbytes, sequential)


def _build(workdir: str, nruns: int, run_rows: int) -> None:
    store = BlockStore(workdir, "runs", IOLedger(), columns=("k", "p"))
    rng = np.random.default_rng(11)
    for i in range(nruns):
        k = np.sort(rng.integers(0, 1 << 40, run_rows))
        store.append_run(k, i * run_rows + np.arange(run_rows))


def _merge_once(workdir: str, max_fanin: int, overlap: bool):
    """Cascade-merge the store and re-emit the merged stream to an output
    store (read + compute + write per pass, the full pipeline shape)."""
    ledger, gauge = ThrottledLedger(), MemoryGauge()
    store = BlockStore.attach(workdir, "runs", ledger,
                              columns=("k", "p"), gauge=gauge)
    out = BlockStore(workdir, f"out_{int(overlap)}", ledger,
                     columns=("k", "p"), gauge=gauge, fresh=True)
    digests = [hashlib.sha256() for _ in store.columns]
    t0 = time.perf_counter()
    rows = 0
    with write_behind([out], ledger, gauge, enabled=overlap) as sinks:
        for cols in merge_runs(store, key=0, max_fanin=max_fanin,
                               overlap=overlap):
            rows += cols[0].shape[0]
            for dg, c in zip(digests, cols):
                dg.update(np.ascontiguousarray(c).tobytes())
            sinks[0].append_run(*cols)
    wall = time.perf_counter() - t0
    out.destroy()
    return {
        "seconds": round(wall, 4),
        "rows": rows,
        "bytes_read": ledger.bytes_read,
        "bytes_written": ledger.bytes_written,
        "read_wait_s": round(ledger.read_wait_s, 4),
        "write_wait_s": round(ledger.write_wait_s, 4),
        "hidden_s": round(ledger.overlap_s, 4),
        "peak_rows": gauge.peak_rows,
    }, tuple(dg.hexdigest() for dg in digests)


def run(nruns=8, run_rows=16384, max_fanin=4):
    rows = []
    with tempfile.TemporaryDirectory() as d:
        _build(d, nruns, run_rows)
        serial, ser_digest = _merge_once(d, max_fanin, overlap=False)
        overl, ov_digest = _merge_once(d, max_fanin, overlap=True)
    assert ov_digest == ser_digest, (
        "overlap=True merge is NOT bit-identical to serial")
    assert overl["seconds"] < serial["seconds"], (
        f"overlapped wall {overl['seconds']}s did not beat serial "
        f"{serial['seconds']}s on an I/O-bound cascade")
    waits = overl["read_wait_s"] + overl["write_wait_s"]
    frac = overl["hidden_s"] / max(overl["hidden_s"] + waits, 1e-9)
    for mode, stats in (("serial", serial), ("overlap", overl)):
        rows.append({"mode": mode, **stats, "identical": True})
    summary = {
        "nruns": nruns, "run_rows": run_rows, "max_fanin": max_fanin,
        "serial_seconds": serial["seconds"],
        "overlap_seconds": overl["seconds"],
        "speedup": round(serial["seconds"] / overl["seconds"], 3),
        "overlap_frac": round(frac, 3),
        "sweep": rows,
    }
    print_table(
        "overlapped vs serial I/O-bound cascade "
        "(nruns=%d, run_rows=%d, fanin=%d)" % (nruns, run_rows, max_fanin),
        rows, ["mode", "seconds", "read_wait_s", "write_wait_s", "hidden_s",
               "bytes_read", "bytes_written", "peak_rows", "identical"])
    print(f"speedup x{summary['speedup']}  "
          f"overlap_frac {summary['overlap_frac']}")
    save_json("overlap", summary)
    return summary


if __name__ == "__main__":
    run()
