"""Paper §I microbenchmark: 'hashing 2^30 integers required 1.34 s while
sorting them into 65,536-sized chunks requires 5.134 s' — the relabel
approach pays ~4x over hashing per element, but buys sequential downstream
phases.  We reproduce the RATIO at container-feasible sizes."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.hashing import feistel_permute
from repro.core.types import GraphConfig

from .common import print_table, save_json, time_fn


def run(log_n=22, chunk=65_536):
    n = 1 << log_n
    cfg = GraphConfig(scale=log_n)
    x = jax.random.randint(jax.random.PRNGKey(0), (n,), 0, n, jnp.int32)

    hash_fn = jax.jit(lambda v: feistel_permute(v, cfg.scale, cfg.seed))
    t_hash = time_fn(hash_fn, x)

    def chunk_sort(v):
        return jnp.sort(v.reshape(-1, chunk), axis=1)

    sort_fn = jax.jit(chunk_sort)
    t_sort = time_fn(sort_fn, x)

    rows = [{
        "n": n, "hash_s": t_hash, "chunk_sort_s": t_sort,
        "ratio": t_sort / t_hash, "paper_ratio": 5.134 / 1.34,
    }]
    print_table("§I: hash vs 65536-chunk sort", rows,
                ["n", "hash_s", "chunk_sort_s", "ratio", "paper_ratio"])
    save_json("hash_vs_sort", rows)
    return rows


if __name__ == "__main__":
    run()
