"""Shared benchmark utilities: phase timing, normalization, table printing."""

from __future__ import annotations

import json
import os
import time
from typing import Callable, Dict, List

import jax


def time_fn(fn: Callable, *args, repeats: int = 3, **kw) -> float:
    """Best-of-N wall time with block_until_ready on pytree outputs."""
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn(*args, **kw)
        jax.block_until_ready(out)
        best = min(best, time.perf_counter() - t0)
    return best


def normalized(t: float, scale: int, base_scale: int = 16) -> float:
    """The paper's Fig. 2/4 normalization: divide by 2^(s-16)."""
    return t / (2.0 ** (scale - base_scale))


def print_table(title: str, rows: List[Dict], cols: List[str]):
    print(f"\n== {title} ==")
    header = " | ".join(f"{c:>14s}" for c in cols)
    print(header)
    print("-" * len(header))
    for r in rows:
        print(" | ".join(
            f"{r[c]:14.4f}" if isinstance(r[c], float) else f"{str(r[c]):>14s}"
            for c in cols))


def save_json(name: str, payload):
    os.makedirs("experiments/bench", exist_ok=True)
    path = f"experiments/bench/{name}.json"
    with open(path, "w") as f:
        json.dump(payload, f, indent=1)
    print(f"[saved {path}]")
