"""The out-of-core walk sampler vs the in-memory host oracle.

Three measurements per scale:

  hops/s    walker advancement throughput of external_walks (frontier
            sort -> CSR sort-merge-join -> owner partition, all on disk)
            against host_walks over the same resident CSR — the price of
            never materializing the graph.
  seq_frac  fraction of external I/O transfers that are sequential (the
            paper's Fig.-2 discipline applied to traversal: must be 1.0).
  peak      MemoryGauge peak resident rows at fixed chunk_edges — flat
            across scales, while the host oracle's working set is the CSR.
"""

from __future__ import annotations

import tempfile
import time

import numpy as np

from repro.core.blockstore import IOLedger, MemoryGauge
from repro.core.external import StreamingGenerator
from repro.core.types import GraphConfig
from repro.data.walks import (
    concat_bucket_csr, external_walks, host_walks, start_vertex)

from .common import print_table, save_json


def run(scales=(10, 12, 14), chunk=1 << 10, nb=4, walkers=256, length=16):
    rows = []
    for s in scales:
        cfg = GraphConfig(scale=s, nb=nb, chunk_edges=chunk, edge_factor=4,
                          shuffle_variant="external")
        with tempfile.TemporaryDirectory() as d:
            _, csr, _ = StreamingGenerator(cfg, d).run()
            offv, adjv = concat_bucket_csr(csr)

            wid = np.arange(walkers, dtype=np.uint32)
            starts = start_vertex(0, wid, cfg.n)
            t0 = time.perf_counter()
            ref = host_walks(offv, adjv, starts, length, 0, n=cfg.n,
                             walker_ids=wid)
            host_s = time.perf_counter() - t0

            ledger, gauge = IOLedger(), MemoryGauge()
            t0 = time.perf_counter()
            res = external_walks(cfg, d, num_walkers=walkers, length=length,
                                 seed=0, ledger=ledger, gauge=gauge)
            ext_s = time.perf_counter() - t0
            np.testing.assert_array_equal(np.asarray(res.walks), ref)

            hops = walkers * length
            ops = (ledger.seq_reads + ledger.seq_writes
                   + ledger.rand_reads + ledger.rand_writes)
            rows.append({
                "scale": s, "n": cfg.n,
                "host_hops_s": hops / max(host_s, 1e-9),
                "ext_hops_s": hops / max(ext_s, 1e-9),
                "slowdown": ext_s / max(host_s, 1e-9),
                "seq_frac": (ledger.seq_reads + ledger.seq_writes) / max(ops, 1),
                "peak_rows": gauge.peak_rows,
                "csr_rows": int(offv.shape[0] + adjv.shape[0]),
            })
    print_table(
        "external vs host walk sampler (walkers=%d, length=%d, chunk=%d)"
        % (walkers, length, chunk),
        rows, ["scale", "n", "host_hops_s", "ext_hops_s", "slowdown",
               "seq_frac", "peak_rows", "csr_rows"])
    save_json("external_walks", rows)
    return rows


if __name__ == "__main__":
    run()
