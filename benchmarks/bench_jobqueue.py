"""Multi-tenant job queue vs serial drain: fleet utilization and makespan.

The same batch of jobs (one fused-walk job + one plain-walk job + one
walkless job, all recompute-shuffle so their exchange phases are
steal-eligible) drains twice through a 2-host loopback cluster:

  serial   max_concurrent=1 — each job owns the fleet end to end, hosts
           idle whenever their half of a barrier finishes early
  queued   max_concurrent=len(jobs) — job barriers interleave, hosts lease
           (or steal) another job's tasks instead of idling

Reported per mode: makespan, summed busy-seconds, utilization
(busy / (hosts x makespan)) and steal count, plus the
OVERLAP FACTOR = serial makespan / queued makespan.  Parity is asserted
per job: the queued drain's CSR + corpus shas must equal the serial
drain's — overlap is a scheduling effect, never a numeric one.

At bench scale (seconds-long drains on one box) makespan is dominated by
scheduling noise, so the asserted trajectory metric is UTILIZATION: the
queued drain must keep the fleet strictly busier than the serial drain —
that is the quantity work-stealing exists to move, and it is stable run
to run where the overlap factor is not.
"""

from __future__ import annotations

import hashlib
import json
import os
import tempfile
import time

import numpy as np

from repro.core.cluster import ClusterSpec, LocalExecBackend
from repro.core.corpus import ShardedWalks, manifest_name
from repro.core.jobqueue import JobScheduler
from repro.core.types import GraphConfig

from .common import print_table, save_json


def _jobs(scale, nb, chunk, edge_factor, walkers, length):
    cfg = GraphConfig(scale=scale, nb=nb, chunk_edges=chunk,
                      edge_factor=edge_factor, shuffle_variant="recompute",
                      transport="socket")
    return [
        dict(cfg=cfg.with_(seed=1), fuse_gen_relabel=True, fuse_walks=True,
             walks=[(walkers, length, 1, "a.npy"),
                    (walkers, length, 2, "b.npy")]),
        dict(cfg=cfg.with_(seed=2), walks=[(walkers, length, 7, "c.npy")]),
        dict(cfg=cfg.with_(scale=scale + 1, seed=3), fuse_gen_relabel=True,
             walks=[]),
    ]


def _sha_file(path):
    with open(path, "rb") as f:
        return hashlib.sha256(f.read()).hexdigest()


def _artifacts(ctrl_dir, jobdef, tag):
    wd = os.path.join(ctrl_dir, tag)
    with open(os.path.join(wd, "graph_manifest.json")) as f:
        m = json.load(f)
    h = hashlib.sha256()
    for b in m["buckets"]:
        for k in ("offv", "adjv"):
            h.update(_sha_file(os.path.join(b["workdir"], b[k])).encode())
    out = {"csr": h.hexdigest()}
    for (_, _, _, o) in jobdef.get("walks", []):
        arr = np.ascontiguousarray(
            np.array(ShardedWalks(os.path.join(wd, manifest_name(o)))))
        out[o] = hashlib.sha256(arr.tobytes()).hexdigest()
    return out


def _drain(jobs, max_concurrent, num_hosts, nb):
    with tempfile.TemporaryDirectory() as root:
        spec = ClusterSpec.local(num_hosts, os.path.join(root, "hosts"),
                                 nb=nb)
        env = {"PYTHONPATH": os.environ.get("PYTHONPATH", "src")}
        t0 = time.perf_counter()
        with JobScheduler(spec, os.path.join(root, "ctrl"),
                          backend=LocalExecBackend(env=env),
                          max_concurrent=max_concurrent,
                          heartbeat_timeout=30.0) as sched:
            handles = [sched.submit(j["cfg"], walks=j.get("walks", ()),
                                    fuse_walks=j.get("fuse_walks", False),
                                    fuse_gen_relabel=j.get(
                                        "fuse_gen_relabel", False))
                       for j in jobs]
            summary = sched.drain()
            wall = time.perf_counter() - t0
            assert not summary["dead_letters"], summary["dead_letters"]
            assert all(j["status"] == "done" for j in summary["jobs"])
            shas = {h.tag: _artifacts(sched.root, d, h.tag)
                    for h, d in zip(handles, jobs)}
        return {
            "makespan_s": summary["makespan_s"],
            "wall_s": wall,
            "busy_s": summary["busy_s"],
            "utilization": summary["utilization"],
            "steals": summary["steals"],
        }, shas


def run(scale=8, nb=4, chunk=1 << 8, edge_factor=4, walkers=16, length=4,
        num_hosts=2):
    jobs = _jobs(scale, nb, chunk, edge_factor, walkers, length)
    serial, sha_serial = _drain(jobs, 1, num_hosts, nb)
    queued, sha_queued = _drain(jobs, len(jobs), num_hosts, nb)
    assert sha_queued == sha_serial, "queued drain diverged from serial"
    assert queued["utilization"] > serial["utilization"], (
        f"work-stealing drain left the fleet idler than serial: "
        f"{queued['utilization']:.4f} <= {serial['utilization']:.4f}")

    overlap = serial["makespan_s"] / max(queued["makespan_s"], 1e-9)
    rows = []
    for mode, r in (("serial", serial), ("queued", queued)):
        rows.append({"mode": mode,
                     "makespan_s": round(r["makespan_s"], 3),
                     "busy_s": round(r["busy_s"], 3),
                     "utilization": round(r["utilization"], 4),
                     "steals": r["steals"]})
    print_table("job queue: serial vs work-stealing drain "
                f"(scale {scale}/{scale + 1}, {num_hosts} hosts, "
                f"{len(jobs)} jobs)",
                rows, ["mode", "makespan_s", "busy_s", "utilization",
                       "steals"])
    print(f"overlap factor (serial/queued makespan): {overlap:.2f}x")

    result = {"scale": scale, "num_hosts": num_hosts, "jobs": len(jobs),
              "serial": serial, "queued": queued,
              "overlap_factor": round(overlap, 4),
              "parity": "ok"}
    save_json("jobqueue", result)
    return result
