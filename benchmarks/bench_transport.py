"""Exchange-transport throughput: filesystem vs framed-TCP bucket exchange.

For each transport backend, run the full partitioned pipeline (generate +
relabel + redistribute + CSR) plus a walk corpus — every exchange site rides
the transport under test — and report:

  wall time        end-to-end, and the exchange-heavy phases separately
  exchanged bytes  exch_MB = bytes handed to the transport, counted once per
                   run on BOTH backends (TransportStats); wire_MB = bytes
                   actually framed over TCP (socket only — on a shared
                   filesystem those same exch_MB cross the interconnect
                   twice, the 2x term in core/external.py's cost table)
  parity           per-column sha256 of the CSR bucket files + corpus —
                   asserted identical across backends, every point

Loopback sockets understate a real network's latency but exercise the full
framing/ack path, so the comparison isolates protocol overhead: the fs
backend does less syscall work per run on one host, while the socket backend
is the one that scales past it.
"""

from __future__ import annotations

import hashlib
import tempfile
import time

import numpy as np

from repro.core.phases import PartitionedGenerator
from repro.core.types import GraphConfig

from .common import print_table, save_json


def _pipeline(cfg, workdir, walkers, length):
    t0 = time.perf_counter()
    with PartitionedGenerator(cfg, workdir, max_workers=0,
                              exchange_servers=2) as part:
        csr, ledger = part.run()
        t_gen = time.perf_counter() - t0
        t1 = time.perf_counter()
        walks = np.asarray(part.walk_corpus(walkers, length, seed=0)).copy()
        t_walk = time.perf_counter() - t1
        phase_secs = {r["phase"]: r["seconds"]
                      for r in part.orchestrator.report()}
        h = hashlib.sha256()
        for o, a in csr:
            h.update(np.asarray(o).tobytes())
            h.update(np.asarray(a).tobytes())
        h.update(walks.tobytes())
        return {
            "gen_s": t_gen,
            "walk_s": t_walk,
            "relabel_s": phase_secs.get("relabel", 0.0),
            "redistribute_s": phase_secs.get("redistribute", 0.0),
            "bytes_written": ledger.bytes_written,
            "exch_bytes": part.exchange_stats.bytes_sent,
            "exch_frames": part.exchange_stats.frames_sent,
            "wire_bytes": part.exchange_stats.bytes_recv,
            "sha": h.hexdigest(),
        }


def run(scales=(10, 12), nb=4, chunk=1 << 10, edge_factor=4,
        walkers=64, length=8):
    rows = []
    for s in scales:
        shas = {}
        for transport in ("fs", "socket"):
            cfg = GraphConfig(scale=s, nb=nb, chunk_edges=chunk,
                              edge_factor=edge_factor,
                              shuffle_variant="external", transport=transport)
            with tempfile.TemporaryDirectory() as d:
                r = _pipeline(cfg, d, walkers, length)
            shas[transport] = r.pop("sha")
            exch_mb = r["exch_bytes"] / 1e6
            rows.append({
                "scale": s, "transport": transport,
                "gen_s": round(r["gen_s"], 3),
                "walk_s": round(r["walk_s"], 3),
                "relabel_s": round(r["relabel_s"], 3),
                "redistribute_s": round(r["redistribute_s"], 3),
                "exch_MB": round(exch_mb, 2),
                "exch_frames": r["exch_frames"],
                "wire_MB": round(r["wire_bytes"] / 1e6, 2),
                "exch_MB_per_s": round(
                    exch_mb / max(r["gen_s"] + r["walk_s"], 1e-9), 2),
            })
        assert shas["fs"] == shas["socket"], \
            f"transport parity broken at scale {s}: {shas}"
        print(f"scale {s}: fs/socket outputs bit-identical "
              f"(sha256 {shas['fs'][:16]}...)")
    print_table("exchange transport: fs vs framed TCP (loopback)", rows,
                ["scale", "transport", "gen_s", "walk_s", "relabel_s",
                 "redistribute_s", "exch_MB", "exch_frames", "wire_MB",
                 "exch_MB_per_s"])
    save_json("transport", {"rows": rows})
    return rows


if __name__ == "__main__":
    run()
