"""Skew-aware shard rebalancing vs the static contiguous map.

The same skewed RMAT generation + walk corpus (a=0.70 quadrant mix — the
initial edge partition concentrates ~70% of its bytes on the first two
buckets, so host 0 of a 2-host contiguous split is a built-in straggler)
runs twice on a 2-host loopback cluster:

  static      the historical contiguous ownership, never rewritten
  rebalanced  ClusterGenerator(rebalance=True): at each phase barrier the
              controller snapshots the IOLedger's per-bucket byte counters,
              plans a greedy migration off the hottest host
              (core/shardmap.plan_rebalance) and ships the bucket shards
              over the exchange transport (resumable MIGRATE frames)

Parity is HARD-ASSERTED: CSR + corpus shas of the rebalanced run must
equal the static run's — the map changes where bytes live, never what
they are.  At bench scale makespans are dominated by scheduling noise, so
the asserted trajectory metric is the BYTE BALANCE the rebalancer exists
to move: the hottest host's share of per-bucket bytes under the final
(rebalanced) map must sit strictly below the same run's share under the
static grouping.  Both numbers come from deterministic ledger accounting,
so the gate is stable run to run; makespan and busy-seconds land in the
BENCH json as wall leaves for the PR-over-PR trajectory diff, and the raw
per-bucket byte counters are surfaced verbatim (`bucket_bytes`).
"""

from __future__ import annotations

import hashlib
import json
import os
import tempfile
import time

import numpy as np

from repro.core.cluster import ClusterGenerator, ClusterSpec, LocalExecBackend
from repro.core.shardmap import ShardMap
from repro.core.types import GraphConfig

from .common import print_table, save_json


def _sha_file(path):
    with open(path, "rb") as f:
        return hashlib.sha256(f.read()).hexdigest()


def _artifacts(ctrl_dir, walks):
    with open(os.path.join(ctrl_dir, "graph_manifest.json")) as f:
        m = json.load(f)
    h = hashlib.sha256()
    for b in m["buckets"]:
        for k in ("offv", "adjv"):
            h.update(_sha_file(os.path.join(b["workdir"], b[k])).encode())
    arr = np.ascontiguousarray(np.array(walks))
    return {"csr": h.hexdigest(),
            "corpus": hashlib.sha256(arr.tobytes()).hexdigest()}


def _host_bytes(loads, owners, num_hosts):
    out = [0] * num_hosts
    for b, v in loads.items():
        out[owners[int(b)]] += v
    return out


def _run(cfg, num_hosts, walkers, length, rebalance):
    with tempfile.TemporaryDirectory() as root:
        spec = ClusterSpec.local(num_hosts, os.path.join(root, "hosts"),
                                 nb=cfg.nb)
        env = {"PYTHONPATH": os.environ.get("PYTHONPATH", "src")}
        gen = ClusterGenerator(cfg, spec, os.path.join(root, "ctrl"),
                               backend=LocalExecBackend(env=env),
                               rebalance=rebalance)
        try:
            t0 = time.perf_counter()
            gen.run()
            walks = gen.walk_corpus(walkers, length, seed=3)
            wall = time.perf_counter() - t0
            ctl = gen.controller
            loads = ctl.bucket_loads_snapshot()
            migrations = [e for e in ctl.task_log
                          if e["key"].startswith("rebalance[") and e["ok"]]
            stats = {
                "wall_seconds": round(wall, 3),
                "busy_s": round(sum(ctl.busy_seconds.values()), 3),
                "map_version": ctl.map_version(),
                "owners": list(ctl.shard_map.owners),
                "migrations": len(migrations),
                "bucket_bytes": {str(b): int(v)
                                 for b, v in sorted(loads.items())},
                "host_bytes": _host_bytes(loads, ctl.shard_map.owners,
                                          num_hosts),
            }
            shas = _artifacts(gen.workdir, walks)
        finally:
            gen.close()
        return stats, shas


def run(scale=10, nb=4, chunk=1 << 10, edge_factor=8, walkers=64, length=6,
        num_hosts=2):
    # a=0.70 pushes ~85% of RMAT sources into the low half of the id
    # space: the static contiguous split makes host 0 the straggler.
    cfg = GraphConfig(scale=scale, nb=nb, chunk_edges=chunk,
                      edge_factor=edge_factor,
                      a=0.70, b=0.15, c=0.10, d=0.05,
                      shuffle_variant="external", transport="socket")
    static, sha_static = _run(cfg, num_hosts, walkers, length,
                              rebalance=False)
    rebal, sha_rebal = _run(cfg, num_hosts, walkers, length, rebalance=True)

    assert sha_rebal == sha_static, (
        "rebalanced run diverged from static map")
    assert static["map_version"] == 0 and static["migrations"] == 0
    assert rebal["map_version"] > 0 and rebal["migrations"] > 0, (
        "skewed load never triggered a migration")

    # The byte-balance gate: group the rebalanced run's own per-bucket
    # bytes by its final map vs by the static contiguous map.  Identical
    # loads, two groupings — the hottest host must strictly shed bytes.
    loads = {int(b): v for b, v in rebal["bucket_bytes"].items()}
    static_owners = ShardMap.contiguous(nb, num_hosts).owners
    max_static = max(_host_bytes(loads, static_owners, num_hosts))
    max_rebal = max(_host_bytes(loads, rebal["owners"], num_hosts))
    assert max_rebal < max_static, (
        f"rebalance did not shed bytes off the hot host: "
        f"{max_rebal} >= {max_static}")

    total = sum(loads.values()) or 1
    rows = []
    for mode, r, mx in (("static", static, max_static),
                        ("rebalanced", rebal, max_rebal)):
        rows.append({"mode": mode,
                     "wall_seconds": r["wall_seconds"],
                     "busy_s": r["busy_s"],
                     "migrations": r["migrations"],
                     "max_host_share": round(mx / total, 4)})
    print_table(f"skew rebalance (scale {scale}, nb {nb}, {num_hosts} "
                "hosts, a=0.70 RMAT)",
                rows, ["mode", "wall_seconds", "busy_s", "migrations",
                       "max_host_share"])
    print(f"hot-host bytes: static {max_static} -> rebalanced {max_rebal} "
          f"({100 * (max_static - max_rebal) / max_static:.1f}% shed)")

    result = {"scale": scale, "nb": nb, "num_hosts": num_hosts,
              "static": static, "rebalanced": rebal,
              "max_host_bytes_static": int(max_static),
              "max_host_bytes_rebalanced": int(max_rebal),
              "parity": "ok"}
    save_json("skew", result)
    return result
