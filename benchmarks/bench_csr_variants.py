"""Paper Fig. 2 CSR curve + §III-B7: scatter-CSR cost grows super-linearly
(random access), sorted-merge CSR stays linear.  Measured two ways:

  device       — wall time of build_csr_scatter vs build_csr_sorted across
                 scales
  host         — the out-of-core generator's I/O ledger: random vs sequential
                 block transfers for the two variants (the paper's actual
                 cost model, measured rather than argued)
  partitioned  — the same two variants under REAL process parallelism
                 (PartitionedGenerator, csr_variant="scatter" ported to the
                 bucket kernels): wall time + random-write blowup per worker
"""

from __future__ import annotations

import tempfile
import time

from repro.core.csr import build_csr_scatter, build_csr_sorted
from repro.core.external import StreamingGenerator
from repro.core.phases import PartitionedGenerator
from repro.core.pipeline import generate_edges
from repro.core.redistribute import redistribute, redistribute_sorted
from repro.core.relabel import relabel_ring
from repro.core.shuffle import distributed_shuffle
from repro.core.types import GraphConfig
from repro.distributed.collectives import flat_mesh

from .common import normalized, print_table, save_json, time_fn


def run(scales=(10, 12, 14), host_scale=10):
    mesh = flat_mesh(1)
    rows = []
    for s in scales:
        cfg = GraphConfig(scale=s, nb=1, capacity_factor=3.0)
        pv = distributed_shuffle(cfg, mesh)
        src, dst = generate_edges(cfg, mesh)
        ns, nd = relabel_ring(cfg, mesh, src, dst, pv)
        owned_s = redistribute_sorted(cfg, mesh, ns, nd)
        owned_u = redistribute(cfg, mesh, ns, nd)
        rows.append({
            "scale": s,
            "sorted_norm": normalized(
                time_fn(lambda: build_csr_sorted(cfg, mesh, owned_s)), s),
            "scatter_norm": normalized(
                time_fn(lambda: build_csr_scatter(cfg, mesh, owned_u)), s),
        })
    print_table("CSR variants, device time / 2^(s-16) [s]",
                rows, ["scale", "sorted_norm", "scatter_norm"])

    # host I/O ledger (the paper's cost unit), now per phase: the orchestrator
    # snapshots the ledger around every phase, so the CSR phase's random-I/O
    # blowup (Fig. 2) is attributed to the CSR phase alone instead of being
    # smeared over a whole-run total.
    io_rows, phase_rows = [], []
    for variant in ("sorted", "scatter"):
        cfg = GraphConfig(scale=host_scale, nb=2, chunk_edges=1 << 10,
                          capacity_factor=4.0)
        with tempfile.TemporaryDirectory() as d:
            gen = StreamingGenerator(cfg, d)
            _, _, ledger = gen.run(csr_variant=variant)
        io_rows.append({"variant": variant, **ledger.as_dict()})
        phase_rows += [{"variant": variant, **rec} for rec in gen.orchestrator.report()]
    print_table("CSR variants, host out-of-core I/O ledger (totals)",
                io_rows, ["variant", "seq_reads", "seq_writes",
                          "rand_reads", "rand_writes"])
    print_table("CSR variants, per-phase ledger deltas",
                phase_rows, ["variant", "phase", "seconds", "seq_reads",
                             "seq_writes", "rand_reads", "rand_writes"])

    # partitioned mode (the Fig. 2 blowup under real process parallelism):
    # both variants emit bit-identical CSR files; only the motion differs,
    # and the per-run ledger shows it — scatter's rand_writes vs sorted's
    # zero.
    part_rows = []
    for variant in ("sorted", "scatter"):
        cfg = GraphConfig(scale=host_scale, nb=4, chunk_edges=1 << 10,
                          shuffle_variant="external")
        with tempfile.TemporaryDirectory() as d:
            t0 = time.perf_counter()
            with PartitionedGenerator(cfg, d, max_workers=2) as part:
                _, ledger = part.run(csr_variant=variant)
            part_rows.append({"variant": variant,
                              "seconds": time.perf_counter() - t0,
                              **ledger.as_dict()})
    print_table("CSR variants, partitioned (2 workers) ledger",
                part_rows, ["variant", "seconds", "seq_writes",
                            "rand_writes", "rand_reads"])
    save_json("csr_variants",
              {"device": rows, "host_io": io_rows, "per_phase_io": phase_rows,
               "partitioned": part_rows})
    return {"device": rows, "host_io": io_rows, "partitioned": part_rows}


if __name__ == "__main__":
    run()
