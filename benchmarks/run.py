"""Benchmark harness entry point: `PYTHONPATH=src python -m benchmarks.run`.

One module per paper table/figure (DESIGN.md §6):

  bench_single_node     Fig. 2   per-phase, normalized, single node
  bench_strong_scaling  Fig. 3/4 fixed size, growing shard count
  bench_weak_scaling    Fig. 5   size and shards grow together
  bench_hash_vs_sort    §I       hashing vs chunk-sort microbench
  bench_csr_variants    Fig. 2 CSR + §III-B7  scatter vs sorted (+ I/O ledger)
  bench_external_shuffle §IV-A  external vs device-spill shuffle: peak RSS,
                        per-phase ledger, partitioned-mode wall time
  bench_external_walks  out-of-core walk sampler vs host oracle: hops/s,
                        sequential fraction, peak resident rows
  bench_merge_fanin     cascaded external merge fan-in sweep: pass-count x
                        bytes trade-off, bit-identity asserted per point
  bench_overlap         overlapped (prefetch + write-behind) vs serial I/O
                        on a throttled I/O-bound merge cascade — strict
                        wall-time win gated, sha parity, overlap fraction
  bench_transport       bucket-exchange transport: filesystem {sender}_{seq}
                        runs vs framed TCP (loopback), wall time + wire
                        bytes, bit-identity asserted per point
  bench_jobqueue        multi-tenant job queue: serial vs work-stealing
                        drain of the same job batch on a 2-host cluster —
                        makespan, utilization, overlap factor, parity
  bench_skew            skew-aware shard map: static contiguous ownership
                        vs barrier-time rebalancing of a skewed RMAT on a
                        2-host cluster — hot-host byte share, migrations,
                        per-bucket ledger bytes, parity
  bench_lm              substrate sanity: train/serve throughput
  bench_roofline        deliverable (g): render the dry-run roofline table
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time
import traceback

BENCH_DIR = "experiments/bench"


def _bench_json(name: str, payload) -> str:
    """Machine-readable per-bench summary (BENCH_{name}.json) so the perf
    trajectory is diffable PR-over-PR instead of buried in stdout tables."""
    os.makedirs(BENCH_DIR, exist_ok=True)
    path = os.path.join(BENCH_DIR, f"BENCH_{name}.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=1, default=str)
    print(f"[saved {path}]")
    return path


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="", help="comma-list of bench names")
    ap.add_argument("--fast", action="store_true",
                    help="smaller scales (CI mode)")
    args = ap.parse_args()

    from repro.core.trace import GLOBAL as METRICS, run_metadata

    from . import (bench_csr_variants, bench_external_shuffle,
                   bench_external_walks, bench_hash_vs_sort, bench_jobqueue,
                   bench_lm, bench_merge_fanin, bench_overlap,
                   bench_roofline, bench_single_node, bench_skew,
                   bench_strong_scaling, bench_transport,
                   bench_weak_scaling)

    benches = {
        "single_node": lambda: bench_single_node.run(
            scales=(10, 12) if args.fast else (10, 12, 14, 16)),
        "strong_scaling": lambda: bench_strong_scaling.run(
            scales=(12,) if args.fast else (12, 14),
            shard_counts=(1, 2, 4) if args.fast else (1, 2, 4, 8)),
        "weak_scaling": lambda: bench_weak_scaling.run(
            steps=3 if args.fast else 4),
        "hash_vs_sort": lambda: bench_hash_vs_sort.run(
            log_n=20 if args.fast else 22),
        "csr_variants": lambda: bench_csr_variants.run(
            scales=(10, 12) if args.fast else (10, 12, 14)),
        "external_shuffle": lambda: bench_external_shuffle.run(
            scales=(10, 12) if args.fast else (10, 12, 14),
            worker_counts=(0, 2) if args.fast else (0, 2, 4)),
        "merge_fanin": lambda: bench_merge_fanin.run(
            nruns=128 if args.fast else 512,
            run_rows=512 if args.fast else 2048,
            fanins=(0, 4, 16) if args.fast else (0, 4, 8, 16, 64, 256)),
        # no reduced fast variant: the throttled I/O toll already keeps the
        # point to a few seconds, and shrinking it further would let thread
        # handoff noise into the strict serial-vs-overlap wall-time gate.
        "overlap": lambda: bench_overlap.run(
            nruns=8, run_rows=16384, max_fanin=4),
        "transport": lambda: bench_transport.run(
            scales=(9, 10) if args.fast else (10, 12),
            walkers=32 if args.fast else 64,
            length=6 if args.fast else 8),
        # no reduced fast variant: below this batch size the per-job work
        # is so small that cross-job barrier interleaving costs more than
        # the idle time it fills and the overlap factor dips under 1.0 —
        # a fast point would benchmark the scheduler's floor, not its win.
        "jobqueue": lambda: bench_jobqueue.run(
            scale=9, walkers=32, length=6),
        # one point, no fast variant: the byte-balance gate needs enough
        # skewed bytes for a strict-improvement migration to exist, and
        # scale 10 already runs in CI time.
        "skew": lambda: bench_skew.run(
            scale=10, walkers=64, length=6),
        "external_walks": lambda: bench_external_walks.run(
            scales=(9, 10) if args.fast else (10, 12, 14),
            walkers=64 if args.fast else 256,
            length=8 if args.fast else 16),
        "lm": bench_lm.run,
        "roofline": bench_roofline.run,
    }
    chosen = [s for s in args.only.split(",") if s] or list(benches)

    failed, summary = [], []
    for name in chosen:
        print(f"\n######## {name} ########")
        t0 = time.time()
        # Per-bench metrics isolation: the process-wide registry accumulates
        # whatever drivers ran; clearing here scopes `combined()` to THIS
        # bench's phases.  The snapshot (trace.unified_snapshot schema) rides
        # in every BENCH json under "metrics"; diff.py ignores the subtree.
        METRICS.clear()
        try:
            result = benches[name]()
            secs = time.time() - t0
            print(f"[{name} done in {secs:.1f}s]")
            entry = {"bench": name, "ok": True,
                     "wall_seconds": round(secs, 3), "fast": args.fast,
                     "metrics": METRICS.combined()}
            try:
                json.dumps(result, default=str)
                entry["result"] = result
            except TypeError:
                entry["result"] = None
            _bench_json(name, entry)
            summary.append({k: entry[k] for k in
                            ("bench", "ok", "wall_seconds", "fast")})
        except Exception:
            traceback.print_exc()
            secs = time.time() - t0
            _bench_json(name, {"bench": name, "ok": False,
                               "wall_seconds": round(secs, 3),
                               "fast": args.fast,
                               "metrics": METRICS.combined()})
            summary.append({"bench": name, "ok": False,
                            "wall_seconds": round(secs, 3), "fast": args.fast})
            failed.append(name)
    _bench_json("summary", {"benches": summary, "failed": failed,
                            "meta": run_metadata()})
    if failed:
        print(f"\nFAILED: {failed}")
        sys.exit(1)
    print("\nAll benchmarks completed.")


if __name__ == "__main__":
    main()
